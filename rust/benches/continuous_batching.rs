//! Continuous vs. blocking batching on a mixed-length MockModel workload.
//!
//! The model compiles a single batch bucket (the common XLA deployment:
//! one static shape), so every forward pass costs the full bucket whether
//! its rows carry live sequences or padding. The **blocking** baseline is
//! the old engine behavior at the batch level: requests are grouped into
//! bucket-sized waves and each wave runs to completion before the next
//! starts — short sequences finish early but their slots sit idle (padded)
//! until the wave's slowest sequence drains. The **continuous** path admits
//! the whole workload into one scheduler, which retires finished sequences
//! each step and backfills freed slots from the pending queue, keeping the
//! bucket full of real work.
//!
//! Reported: mean wall-time per sample (completion latency from workload
//! start) and the deterministic cost currency — total batch rows paid per
//! sample. The row-step assertion guards the scheduling win even on noisy
//! machines.

use std::sync::Arc;
use std::time::Instant;

use ssmd::engine::{SeqParams, SpecParams, SpecScheduler, StepPool};
use ssmd::engine::{MockModel, Prompt};
use ssmd::util::bench::{fmt_duration, write_json, BenchResult};
use ssmd::util::rng::Pcg;

const D: usize = 32;
const VOCAB: usize = 8;
const BUCKET: usize = 8;
const N_REQUESTS: usize = 64;

/// Alternating long (fully masked) and short (75% revealed) requests —
/// the mix where blocking batching wastes the most.
fn workload() -> Vec<Prompt> {
    (0..N_REQUESTS)
        .map(|i| {
            let mut p = Prompt::empty(D);
            if i % 2 == 1 {
                for pos in 0..3 * D / 4 {
                    p.0[pos] = Some((pos % VOCAB) as i32);
                }
            }
            p
        })
        .collect()
}

fn model() -> MockModel {
    let mut m = MockModel::new(D, VOCAB, 7);
    m.buckets = vec![BUCKET];
    m
}

/// Planar-phase executor width (STEP_THREADS env; CI runs a 2-thread
/// smoke leg). Results are bitwise identical for any value — the
/// deterministic row-step counters below must not move across legs.
fn step_threads() -> usize {
    std::env::var("STEP_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
        .max(1)
}

struct RunStats {
    mean_wall_per_sample_s: f64,
    total_wall_s: f64,
    row_steps: u64,
    steps: u64,
    backfills: u64,
    preemptions: u64,
    resume_steps: u64,
}

/// Blocking: bucket-sized waves, each driven to completion before the
/// next wave is admitted (no cross-wave backfill).
fn run_blocking(prompts: &[Prompt], params: &SpecParams,
                pool: &Arc<StepPool>) -> RunStats {
    let m = model();
    let mut rng = Pcg::new(1);
    let start = Instant::now();
    let mut latency_sum = 0.0;
    let mut n_done = 0usize;
    let mut row_steps = 0;
    let mut steps = 0;
    for wave in prompts.chunks(BUCKET) {
        let mut sched = SpecScheduler::for_model(&m);
        sched.set_pool(pool.clone());
        for p in wave {
            sched.admit(p, SeqParams::Spec(params.clone()), rng.split());
        }
        while !sched.is_idle() {
            for _ in sched.step(&m) {
                latency_sum += start.elapsed().as_secs_f64();
                n_done += 1;
            }
        }
        row_steps += sched.row_steps();
        steps += sched.steps();
    }
    assert_eq!(n_done, prompts.len());
    RunStats {
        mean_wall_per_sample_s: latency_sum / n_done as f64,
        total_wall_s: start.elapsed().as_secs_f64(),
        row_steps,
        steps,
        backfills: 0,
        preemptions: 0,
        resume_steps: 0,
    }
}

/// Continuous: one scheduler, whole workload admitted up front, retired
/// slots backfilled from the pending queue every step.
fn run_continuous(prompts: &[Prompt], params: &SpecParams,
                  pool: &Arc<StepPool>) -> RunStats {
    let m = model();
    let mut rng = Pcg::new(1);
    let mut sched = SpecScheduler::for_model(&m);
    sched.set_pool(pool.clone());
    let start = Instant::now();
    for p in prompts {
        sched.admit(p, SeqParams::Spec(params.clone()), rng.split());
    }
    let mut latency_sum = 0.0;
    let mut n_done = 0usize;
    while !sched.is_idle() {
        for _ in sched.step(&m) {
            latency_sum += start.elapsed().as_secs_f64();
            n_done += 1;
        }
    }
    assert_eq!(n_done, prompts.len());
    RunStats {
        mean_wall_per_sample_s: latency_sum / n_done as f64,
        total_wall_s: start.elapsed().as_secs_f64(),
        row_steps: sched.row_steps(),
        steps: sched.steps(),
        backfills: sched.backfills(),
        preemptions: sched.evictions(),
        resume_steps: sched.resumes(),
    }
}

/// Continuous batching under a scripted preemption cycle: at fixed step
/// indexes two residents are checkpointed out (lowest priority first)
/// and parked, pending work backfills their slots, and the checkpoints
/// resume later. Everything drains exactly once — and because the
/// eviction points are step-indexed (not timed), the counters below are
/// fully deterministic (and thread-count invariant), so bench_trend can
/// gate on them.
fn run_preemptive(prompts: &[Prompt], params: &SpecParams,
                  pool: &Arc<StepPool>) -> RunStats {
    let m = model();
    let mut rng = Pcg::new(1);
    let mut sched = SpecScheduler::for_model(&m);
    sched.set_pool(pool.clone());
    let start = Instant::now();
    for (i, p) in prompts.iter().enumerate() {
        // Three priority classes so evict_lowest has real choices.
        sched.admit_prio(p, SeqParams::Spec(params.clone()), rng.split(),
                         (i % 3) as i32);
    }
    let mut latency_sum = 0.0;
    let mut n_done = 0usize;
    let mut parked = Vec::new();
    let mut step_no = 0u64;
    while !sched.is_idle() || !parked.is_empty() {
        if step_no == 6 || step_no == 12 {
            for _ in 0..2 {
                if let Some(ck) = sched.evict_lowest() {
                    parked.push(ck);
                }
            }
        }
        if step_no == 24 {
            for ck in parked.drain(..) {
                sched.resume(ck);
            }
        }
        for _ in sched.step(&m) {
            latency_sum += start.elapsed().as_secs_f64();
            n_done += 1;
        }
        step_no += 1;
    }
    assert!(parked.is_empty(), "checkpoints left behind");
    assert_eq!(n_done, prompts.len(),
               "preemption lost or duplicated sequences");
    RunStats {
        mean_wall_per_sample_s: latency_sum / n_done as f64,
        total_wall_s: start.elapsed().as_secs_f64(),
        row_steps: sched.row_steps(),
        steps: sched.steps(),
        backfills: sched.backfills(),
        preemptions: sched.evictions(),
        resume_steps: sched.resumes(),
    }
}

fn main() {
    let params = SpecParams::default();
    let prompts = workload();
    let threads = step_threads();
    let pool = Arc::new(StepPool::new(threads));

    println!("== continuous vs blocking batching ==");
    println!("workload: {N_REQUESTS} requests (50% short / 50% long), \
              D={D}, single bucket {BUCKET}, step_threads={threads}");

    let blocking = run_blocking(&prompts, &params, &pool);
    let continuous = run_continuous(&prompts, &params, &pool);
    let preemptive = run_preemptive(&prompts, &params, &pool);

    println!(
        "{:<12} {:>16} {:>12} {:>10} {:>12} {:>10} {:>8} {:>8}",
        "mode", "wall/sample", "total", "steps", "row-steps", "backfills",
        "preempt", "resume"
    );
    for (name, r) in [("blocking", &blocking), ("continuous", &continuous),
                      ("preemptive", &preemptive)]
    {
        println!(
            "{:<12} {:>16} {:>12} {:>10} {:>12} {:>10} {:>8} {:>8}",
            name,
            fmt_duration(r.mean_wall_per_sample_s),
            fmt_duration(r.total_wall_s),
            r.steps,
            r.row_steps,
            r.backfills,
            r.preemptions,
            r.resume_steps
        );
    }
    println!(
        "row-steps saved: {:.1}%  (wall/sample: {:.2}x)",
        100.0 * (1.0 - continuous.row_steps as f64
                 / blocking.row_steps as f64),
        blocking.mean_wall_per_sample_s / continuous.mean_wall_per_sample_s
    );

    // Deterministic guard: with retirements backfilled every step, the
    // continuous path must pay for strictly fewer batch rows per sample.
    assert!(
        continuous.row_steps < blocking.row_steps,
        "continuous ({}) must beat blocking ({}) in rows paid",
        continuous.row_steps,
        blocking.row_steps
    );
    assert!(continuous.backfills > 0, "workload must exercise backfill");
    // The preemption cycle must actually checkpoint and resume work.
    assert_eq!(preemptive.preemptions, 4, "two evictions of two");
    assert_eq!(preemptive.resume_steps, 4,
               "every checkpoint resumed exactly once");

    // Machine-readable perf artifact (uploaded by CI per PR). This bench
    // always runs its full deterministic workload (it measures one
    // scenario, not timed iterations), so even when the artifact is
    // stamped smoke:true the `extra` fields below — row_steps, steps,
    // backfills — are exact and valid for trend analysis; only the
    // wall-clock entries inherit CI timing noise.
    let results = [
        BenchResult::single("blocking.total_wall_s", blocking.total_wall_s)
            .with_items(N_REQUESTS as f64),
        BenchResult::single("blocking.wall_per_sample_s",
                            blocking.mean_wall_per_sample_s),
        BenchResult::single("continuous.total_wall_s",
                            continuous.total_wall_s)
            .with_items(N_REQUESTS as f64),
        BenchResult::single("continuous.wall_per_sample_s",
                            continuous.mean_wall_per_sample_s),
    ];
    let extra = [
        ("step_threads", threads as f64),
        ("blocking.row_steps", blocking.row_steps as f64),
        ("continuous.row_steps", continuous.row_steps as f64),
        ("blocking.steps", blocking.steps as f64),
        ("continuous.steps", continuous.steps as f64),
        ("continuous.backfills", continuous.backfills as f64),
        // Preemption cycle counters: deterministic (step-indexed evict/
        // resume, thread-count invariant), so the trend gate sees any
        // change in checkpoint/evict/resume bookkeeping.
        ("preemptive.steps", preemptive.steps as f64),
        ("preemptive.row_steps", preemptive.row_steps as f64),
        ("preemptions", preemptive.preemptions as f64),
        ("resume_steps", preemptive.resume_steps as f64),
        (
            "row_steps_saved_frac",
            1.0 - continuous.row_steps as f64 / blocking.row_steps as f64,
        ),
    ];
    match write_json("continuous_batching", &results, &extra) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("BENCH_continuous_batching.json not written: {e}"),
    }
}
