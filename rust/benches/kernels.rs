//! Logits-domain sampling kernels vs the seed's materialized-softmax path.
//!
//! Three comparisons at V ∈ {27, 1k, 50k} (text8 / small-word / GPT2-scale
//! vocabularies), temperatures {0.7, 1.0}:
//!
//! * `draw`: old `temp_probs` (full softmax row allocation) + CDF
//!   categorical vs fused Gumbel-max draw + cached LSE;
//! * `accept`: old full q-row softmax to read one scalar vs log-space
//!   accept from a cached LSE;
//! * `outer`: one scheduler outer loop for a row mid-generation — the old
//!   hot loop drafted and materialized softmax rows for ALL remaining
//!   positions (D_REM) and re-softmaxed a q row per accept test, while
//!   the kernel path draws lazily inside the accept window (W) with
//!   cached LSEs and a reusable residual scratch row.
//!
//! The acceptance gate for this PR is the `outer` ratio at V = 50k:
//! >= 5x, asserted below on tuned builds (the repo sets
//! `target-cpu=native`; on a baseline-ISA build the polynomial kernels
//! lose their vector units, so the assert is reported but not enforced).
//! Results land in `BENCH_kernels.json` via `util::bench::write_json`.

use std::sync::Arc;

use ssmd::engine::kernels::{accept_prob, gumbel_draw_lse,
                            residual_draw_into, row_lse};
use ssmd::engine::softmax::{residual_distribution, softmax_row};
use ssmd::engine::{HybridModel, Prompt, SeqParams, SpecParams,
                   SpecScheduler, StepPool, Window};
use ssmd::util::bench::{bench, print_header, print_result, smoke,
                        write_json, BenchResult};
use ssmd::util::rng::Pcg;

/// Remaining ordering positions the old path drafted every outer loop.
const D_REM: usize = 32;
/// Accept-window width: positions the new path drafts (and both paths
/// accept-test) per outer loop.
const W: usize = 8;

/// Planar-step bench shape: a multi-resident batch at GPT2-scale vocab.
const PB: usize = 8;
const PD: usize = 16;
const PV: usize = 50_000;

/// The seed scheduler's probability builder (pre-fix `softmax_row_temp`
/// semantics are close enough to the repaired one for timing; the seed's
/// extra scaled-Vec allocation is reproduced below for fidelity).
fn temp_probs_seed(logits: &[f32], temperature: f64) -> Vec<f64> {
    if (temperature - 1.0).abs() < 1e-12 {
        softmax_row(logits)
    } else {
        // Seed implementation: scale into an intermediate f32 vec, then
        // softmax it (what `engine/softmax.rs:31-35` used to do).
        let scaled: Vec<f32> = logits
            .iter()
            .map(|&x| (x as f64 / temperature) as f32)
            .collect();
        softmax_row(&scaled)
    }
}

fn gen_rows(rng: &mut Pcg, n: usize, v: usize) -> Vec<Vec<f32>> {
    (0..n)
        .map(|_| {
            (0..v)
                .map(|_| ((rng.f64() * 8.0 - 4.0) as f32))
                .collect()
        })
        .collect()
}

/// One old-path outer loop: materialize + store draft softmax rows for
/// every remaining position, then accept-sweep the window with a fresh q
/// softmax per test (q = p.clone() at ordering position 0) and the
/// allocating residual on rejection.
fn outer_materialized(rows_p: &[Vec<f32>], rows_q: &[Vec<f32>], temp: f64,
                      rng: &mut Pcg) -> usize {
    let mut draft_probs: Vec<Vec<f64>> = Vec::with_capacity(rows_p.len());
    let mut toks = Vec::with_capacity(rows_p.len());
    for row in rows_p {
        let probs = temp_probs_seed(row, temp);
        toks.push(rng.categorical(&probs));
        draft_probs.push(probs);
    }
    let mut consumed = 0;
    for dd in 0..W {
        let tok = toks[dd];
        let q_row: Vec<f64> = if dd == 0 {
            draft_probs[0].clone()
        } else {
            temp_probs_seed(&rows_q[dd], temp)
        };
        let accept = (q_row[tok] / draft_probs[dd][tok]).min(1.0);
        if rng.f64() < accept {
            consumed += tok;
        } else {
            let res = residual_distribution(&q_row, &draft_probs[dd])
                .unwrap_or(q_row);
            consumed += rng.categorical(&res);
            break;
        }
    }
    consumed
}

/// One kernel-path outer loop: draw only the window (fused Gumbel + LSE),
/// log-space accepts from cached LSEs, residual into a reused scratch row.
fn outer_kernels(rows_p: &[Vec<f32>], rows_q: &[Vec<f32>], temp: f64,
                 rng: &mut Pcg, scratch: &mut Vec<f64>,
                 lse_cache: &mut [f64]) -> usize {
    let inv_t = 1.0 / temp;
    let inv_t32 = inv_t as f32;
    let mut toks = [0usize; W];
    for (dd, tok) in toks.iter_mut().enumerate() {
        let (t, lse) =
            gumbel_draw_lse(&rows_p[dd], inv_t32, rng.next_u64());
        *tok = t;
        lse_cache[dd] = lse;
    }
    let mut consumed = 0;
    for dd in 0..W {
        let tok = toks[dd];
        if dd == 0 {
            // First-position rule: accept probability is exactly 1.
            consumed += tok;
            continue;
        }
        let lse_q = row_lse(&rows_q[dd], inv_t32);
        let accept = accept_prob(rows_q[dd][tok], lse_q, rows_p[dd][tok],
                                 lse_cache[dd], inv_t);
        if rng.f64() < accept {
            consumed += tok;
        } else {
            consumed += residual_draw_into(scratch, &rows_q[dd], lse_q,
                                           &rows_p[dd], lse_cache[dd],
                                           inv_t, rng);
            break;
        }
    }
    consumed
}

/// Template-logits model: `draft_into`/`verify_into` are no-ops once the
/// arena buffers are sized (the templates never change), so a scheduler
/// step's cost is **pure planar-phase work** — exactly what the
/// `step_threads` scaling gate must isolate from model cost.
struct PlanarModel {
    draft: Vec<f32>,
    verify: Vec<f32>,
}

impl PlanarModel {
    fn new(seed: u64) -> PlanarModel {
        let mut rng = Pcg::new(seed);
        let make = |rng: &mut Pcg| -> Vec<f32> {
            (0..PB * PD * PV)
                .map(|_| ((rng.f64() * 8.0 - 4.0) as f32))
                .collect()
        };
        PlanarModel { draft: make(&mut rng), verify: make(&mut rng) }
    }
}

impl HybridModel for PlanarModel {
    type State = ();

    fn seq_len(&self) -> usize {
        PD
    }

    fn vocab(&self) -> usize {
        PV
    }

    fn n_noncausal(&self) -> usize {
        11
    }

    fn n_causal(&self) -> usize {
        1
    }

    fn buckets(&self) -> Vec<usize> {
        vec![PB]
    }

    fn draft(&self, _tokens: &[i32], batch: usize) -> ((), Vec<f32>) {
        ((), self.draft[..batch * PD * PV].to_vec())
    }

    fn verify(&self, _state: &(), _tokens: &[i32], _sigma: &[i32],
              batch: usize) -> Vec<f32> {
        self.verify[..batch * PD * PV].to_vec()
    }

    fn draft_into(&self, _tokens: &[i32], batch: usize,
                  state: &mut Option<()>, logits: &mut Vec<f32>) {
        *state = Some(());
        let need = batch * PD * PV;
        if logits.len() != need {
            logits.clear();
            logits.extend_from_slice(&self.draft[..need]);
        }
    }

    fn verify_into(&self, _state: &(), _tokens: &[i32], _sigma: &[i32],
                   batch: usize, logits: &mut Vec<f32>) {
        let need = batch * PD * PV;
        if logits.len() != need {
            logits.clear();
            logits.extend_from_slice(&self.verify[..need]);
        }
    }
}

/// Admit PB fresh sequences into a (reused, warm) scheduler and drain:
/// returns (outer loops this drain, token streams). Reusing the
/// scheduler keeps the big logits arenas warm across iterations, so the
/// measured time is the planar phases — not a 50 MB arena rebuild.
fn planar_drain(model: &PlanarModel, sched: &mut SpecScheduler)
                -> (u64, Vec<Vec<i32>>) {
    let params = SpecParams {
        window: Window::Constant(4),
        n_verify: 1,
        ..Default::default()
    };
    let steps_before = sched.steps();
    let mut rng = Pcg::new(0x9a7);
    for _ in 0..PB {
        sched.admit(&Prompt::empty(PD), SeqParams::Spec(params.clone()),
                    rng.split());
    }
    let mut out = Vec::new();
    while !sched.is_idle() {
        out.extend(sched.step(model));
    }
    out.sort_by_key(|(id, _)| *id);
    (sched.steps() - steps_before,
     out.into_iter().map(|(_, s)| s.tokens).collect())
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let mut outer_ratio_v50k = 0.0;

    for &v in &[27usize, 1_000, 50_000] {
        let mut rng = Pcg::new(0xbe2c + v as u64);
        print_header(&format!("sampling kernels, V = {v}"));
        let rows_p = gen_rows(&mut rng, D_REM, v);
        let rows_q = gen_rows(&mut rng, W, v);
        let (warm, iters, time) = if v >= 50_000 {
            (3, 10, 0.5)
        } else {
            (10, 50, 0.2)
        };

        for &temp in &[0.7f64, 1.0] {
            let inv_t32 = (1.0 / temp) as f32;
            // -- draw primitive --
            let mut r1 = Pcg::new(7);
            let old_draw = bench(
                &format!("draw/materialized V={v} T={temp}"),
                warm, iters, time,
                || {
                    let probs = temp_probs_seed(&rows_p[0], temp);
                    std::hint::black_box(r1.categorical(&probs));
                },
            );
            let mut r2 = Pcg::new(7);
            let new_draw = bench(
                &format!("draw/gumbel V={v} T={temp}"),
                warm, iters, time,
                || {
                    std::hint::black_box(gumbel_draw_lse(
                        &rows_p[0], inv_t32, r2.next_u64()));
                },
            );
            // -- accept primitive --
            let lse_p = row_lse(&rows_p[0], inv_t32);
            let old_accept = bench(
                &format!("accept/materialized V={v} T={temp}"),
                warm, iters, time,
                || {
                    let q = temp_probs_seed(&rows_q[1], temp);
                    std::hint::black_box((q[3] / 0.25f64).min(1.0));
                },
            );
            let new_accept = bench(
                &format!("accept/lse V={v} T={temp}"),
                warm, iters, time,
                || {
                    let lse_q = row_lse(&rows_q[1], inv_t32);
                    std::hint::black_box(accept_prob(
                        rows_q[1][3], lse_q, rows_p[0][3], lse_p,
                        1.0 / temp));
                },
            );
            // -- full outer loop --
            let mut r3 = Pcg::new(9);
            let old_outer = bench(
                &format!("outer/materialized V={v} T={temp}"),
                warm, iters, time,
                || {
                    std::hint::black_box(outer_materialized(
                        &rows_p, &rows_q, temp, &mut r3));
                },
            );
            let mut r4 = Pcg::new(9);
            let mut scratch = Vec::new();
            let mut lse_cache = [0.0f64; W];
            let new_outer = bench(
                &format!("outer/kernels V={v} T={temp}"),
                warm, iters, time,
                || {
                    std::hint::black_box(outer_kernels(
                        &rows_p, &rows_q, temp, &mut r4, &mut scratch,
                        &mut lse_cache));
                },
            );
            for r in [&old_draw, &new_draw, &old_accept, &new_accept,
                      &old_outer, &new_outer]
            {
                print_result(r);
            }
            let ratio = old_outer.mean_s / new_outer.mean_s;
            println!("  outer speedup: {ratio:.2}x  (draw {:.2}x, \
                      accept {:.2}x)",
                     old_draw.mean_s / new_draw.mean_s,
                     old_accept.mean_s / new_accept.mean_s);
            if v == 50_000 && temp == 0.7 {
                outer_ratio_v50k = ratio;
            }
            results.extend([old_draw, new_draw, old_accept, new_accept,
                            old_outer, new_outer]);
        }
    }

    // ---- multi-resident planar step: step_threads scaling -------------
    // A full scheduler drain on a template-logits model (zero model cost
    // once warm — see PlanarModel), so the measured time is the planar
    // draw/LSE/accept phases themselves. The same seeded workload runs
    // at 1/2/4 threads; token streams must be bitwise identical (the
    // determinism contract), and on tuned multi-core builds 4 threads
    // must clear 2x outer-loop throughput over 1.
    print_header(&format!(
        "planar step, B = {PB}, D = {PD}, V = {PV} (template model)"
    ));
    let planar_model = PlanarModel::new(0x1a7a);
    let mut planar_steps = 0u64;
    let mut planar_speedup_t4 = 0.0;
    let mut base_tokens: Option<Vec<Vec<i32>>> = None;
    let mut t1_mean = 0.0;
    for &threads in &[1usize, 2, 4] {
        let pool = Arc::new(StepPool::new(threads));
        let mut sched = SpecScheduler::for_model(&planar_model);
        sched.set_pool(pool);
        // Warm drain doubles as the determinism pin: identical token
        // streams for every thread count.
        let (steps, tokens) = planar_drain(&planar_model, &mut sched);
        planar_steps = steps;
        match &base_tokens {
            None => base_tokens = Some(tokens),
            Some(base) => assert_eq!(
                base, &tokens,
                "token streams diverged at step_threads={threads}"
            ),
        }
        let r = bench(
            &format!("planar/drain B={PB} V={PV} threads={threads}"),
            1, 3, 0.5,
            || {
                std::hint::black_box(planar_drain(&planar_model,
                                                  &mut sched));
            },
        )
        .with_items(steps as f64);
        print_result(&r);
        if threads == 1 {
            t1_mean = r.mean_s;
        }
        if threads == 4 && t1_mean > 0.0 {
            planar_speedup_t4 = t1_mean / r.mean_s;
        }
        results.push(r);
    }
    println!(
        "  planar outer-loop throughput at 4 threads vs 1: \
         {planar_speedup_t4:.2}x ({planar_steps} outer loops/drain)"
    );

    // Timing-derived extras are pure noise on 1-iteration smoke runs and
    // would pollute the bench-trend extras section (whose contract is
    // "deterministic workload facts, trustworthy under smoke"), so the
    // speedup ratios are only emitted on full measurement runs;
    // planar_steps is deterministic (thread- and smoke-invariant) and is
    // always emitted.
    let det_extra = [("planar_steps", planar_steps as f64)];
    let speedup_extra = [
        ("outer_speedup_v50k", outer_ratio_v50k),
        ("planar_speedup_t4", planar_speedup_t4),
        ("planar_steps", planar_steps as f64),
    ];
    let extras: &[(&str, f64)] =
        if smoke() { &det_extra } else { &speedup_extra };
    let json = write_json("kernels", &results, extras);
    match json {
        Ok(p) => println!("\nwrote {}", p.display()),
        Err(e) => eprintln!("\nBENCH_kernels.json not written: {e}"),
    }

    // Acceptance gates, only enforced on tuned full runs (meaningless
    // under smoke's single iteration, and the polynomial kernels assume
    // the repo's target-cpu=native codegen):
    // * >= 5x on the scheduler outer-loop path at GPT2-scale vocab;
    // * >= 2x outer-loop throughput at step_threads=4 vs 1 (needs >= 4
    //   hardware threads to be meaningful).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if smoke() {
        println!("smoke mode: speedup gates skipped \
                  (outer_speedup_v50k = {outer_ratio_v50k:.2}, \
                   planar_t4 = {planar_speedup_t4:.2})");
    } else if !cfg!(target_feature = "avx2") {
        println!("baseline ISA build: speedup gates reported only \
                  (outer_speedup_v50k = {outer_ratio_v50k:.2}, \
                   planar_t4 = {planar_speedup_t4:.2})");
    } else {
        assert!(
            outer_ratio_v50k >= 5.0,
            "fused draw+accept path must be >= 5x the materialized \
             softmax path at V=50k (got {outer_ratio_v50k:.2}x)"
        );
        println!("outer_speedup_v50k = {outer_ratio_v50k:.2} (gate: 5x)");
        if cores >= 4 {
            assert!(
                planar_speedup_t4 >= 2.0,
                "planar phases must clear 2x outer-loop throughput at \
                 step_threads=4 vs 1 (got {planar_speedup_t4:.2}x)"
            );
            println!(
                "planar_speedup_t4 = {planar_speedup_t4:.2} (gate: 2x)"
            );
        } else {
            println!("only {cores} hardware threads: planar 2x gate \
                      reported only ({planar_speedup_t4:.2}x)");
        }
    }
}
