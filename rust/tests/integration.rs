//! Cross-module integration tests on the mock model: coordinator + server
//! + engine + likelihood wired together exactly as in production, minus
//! PJRT (covered by tests/pjrt_parity.rs and the examples).

use std::collections::BTreeMap;
use std::time::Duration;

use ssmd::coordinator::{
    BatcherConfig, Coordinator, EngineModel, GenRequest, ModelMap,
    SamplerChoice, ScoreRequest,
};
use ssmd::engine::{MdmParams, MockModel, Prompt, SpecParams, Window};
use ssmd::util::json::Json;
use ssmd::util::rng::Pcg;

fn coordinator(seq_len: usize, vocab: usize) -> Coordinator {
    Coordinator::start(
        move || {
            let mut m: ModelMap = BTreeMap::new();
            m.insert(
                "mock".into(),
                Box::new(MockModel::new(seq_len, vocab, 5))
                    as Box<dyn EngineModel>,
            );
            let mut draft_only = MockModel::new(seq_len, vocab, 6);
            draft_only.target_equals_draft = true;
            m.insert("sharp".into(),
                     Box::new(draft_only) as Box<dyn EngineModel>);
            Ok(m)
        },
        BatcherConfig {
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .unwrap()
}

#[test]
fn speculative_beats_mdm_nfe_when_target_matches_draft() {
    // With a perfectly aligned target (q == p) the speculative sampler
    // accepts whole windows: far fewer NFE than a fine-grained MDM run.
    let c = coordinator(32, 8);
    let spec = c
        .generate(GenRequest {
            model: "sharp".into(),
            n_samples: 4,
            sampler: SamplerChoice::Speculative(SpecParams {
                window: Window::Cosine { dtau: 0.1 },
                n_verify: 4,
                ..Default::default()
            }),
            seed: 1,
            ..Default::default()
        })
        .unwrap();
    let mdm = c
        .generate(GenRequest {
            model: "sharp".into(),
            n_samples: 4,
            sampler: SamplerChoice::Mdm(MdmParams {
                steps: 32,
                temperature: 1.0,
            }),
            seed: 1,
            ..Default::default()
        })
        .unwrap();
    let nfe = |r: &ssmd::coordinator::GenResponse| {
        r.samples.iter().map(|s| s.nfe).sum::<f64>()
            / r.samples.len() as f64
    };
    assert!(
        nfe(&spec) < 0.7 * nfe(&mdm),
        "spec {} !< mdm {}",
        nfe(&spec),
        nfe(&mdm)
    );
    c.shutdown();
}

#[test]
fn infilling_respects_prompt_through_the_whole_stack() {
    let c = coordinator(16, 6);
    let mut prompt = Prompt::empty(16);
    prompt.0[0] = Some(3);
    prompt.0[9] = Some(1);
    for sampler in [
        SamplerChoice::Speculative(SpecParams::default()),
        SamplerChoice::Mdm(MdmParams::default()),
    ] {
        let resp = c
            .generate(GenRequest {
                model: "mock".into(),
                n_samples: 3,
                sampler,
                prompt: Some(prompt.clone()),
                seed: 2,
                ..Default::default()
            })
            .unwrap();
        for s in &resp.samples {
            assert_eq!(s.tokens[0], 3);
            assert_eq!(s.tokens[9], 1);
            assert!(s.tokens.iter().all(|&t| (0..6).contains(&t)));
        }
    }
    c.shutdown();
}

#[test]
fn score_likelihood_is_sane_and_sigma_dependent() {
    let c = coordinator(8, 4);
    let tokens = vec![0, 1, 2, 3, 3, 2, 1, 0];
    let a = c
        .score(ScoreRequest {
            model: "mock".into(),
            tokens: tokens.clone(),
            sigma: Some((0..8).collect()),
            seed: None,
            with_posterior: true,
        })
        .unwrap();
    let b = c
        .score(ScoreRequest {
            model: "mock".into(),
            tokens,
            sigma: Some((0..8).rev().collect()),
            seed: None,
            with_posterior: false,
        })
        .unwrap();
    assert!(a.log_likelihood < 0.0);
    assert!(b.log_likelihood < 0.0);
    assert_ne!(a.log_likelihood, b.log_likelihood);
    let post = a.rejection_posterior.unwrap();
    assert_eq!(post.len(), 9);
    assert!((post.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    c.shutdown();
}

#[test]
fn batcher_groups_compatible_requests() {
    // Fire many concurrent compatible requests; the batch-size histogram
    // should record at least one multi-request batch.
    let c = coordinator(16, 6);
    let mut handles = Vec::new();
    for i in 0..8 {
        let cc = c.clone();
        handles.push(std::thread::spawn(move || {
            cc.generate(GenRequest {
                model: "mock".into(),
                n_samples: 2,
                seed: i,
                ..Default::default()
            })
            .unwrap()
        }));
    }
    for h in handles {
        assert_eq!(h.join().unwrap().samples.len(), 2);
    }
    let snap = c.metrics.snapshot();
    let batches = snap
        .get("histograms")
        .and_then(|h| h.get("batch_size"))
        .and_then(|b| b.get("count"))
        .and_then(|c| c.as_f64())
        .unwrap();
    let reqs = snap
        .get("counters")
        .and_then(|x| x.get("requests"))
        .and_then(|x| x.as_f64())
        .unwrap();
    assert_eq!(reqs, 8.0);
    assert!(batches <= reqs, "batches {batches} > requests {reqs}");
    c.shutdown();
}

#[test]
fn full_http_stack_generate_and_score() {
    use std::io::{Read, Write};
    let c = coordinator(8, 4);
    let server = ssmd::server::Server::new(c);
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let stop2 = stop.clone();
    let addr = "127.0.0.1:39482";
    let handle = std::thread::spawn(move || {
        server
            .serve_until(addr, move || {
                stop2.load(std::sync::atomic::Ordering::Relaxed)
            })
            .unwrap();
    });
    // lint: allow(clock-discipline) — test waits for a real TCP
    // listener to come up.
    std::thread::sleep(Duration::from_millis(50));

    let call = |path: &str, body: &str| -> (u16, Json) {
        let mut conn = std::net::TcpStream::connect(addr).unwrap();
        write!(
            conn,
            "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut out = String::new();
        conn.read_to_string(&mut out).unwrap();
        let status: u16 = out[9..12].parse().unwrap();
        let body = out.split_once("\r\n\r\n").unwrap().1;
        (status, Json::parse(body).unwrap())
    };

    let (status, v) = call(
        "/generate",
        r#"{"model":"mock","n":2,"sampler":"mdm","steps":4,"seed":1}"#,
    );
    assert_eq!(status, 200, "{v}");
    assert_eq!(v.get("samples").unwrap().as_arr().unwrap().len(), 2);

    let (status, v) = call(
        "/score",
        r#"{"model":"mock","tokens":[0,1,2,3,0,1,2,3],"seed":3,
            "with_posterior":true}"#,
    );
    assert_eq!(status, 200, "{v}");
    assert!(v.get("rejection_posterior").is_some());

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    handle.join().unwrap();
}

#[test]
fn mdm_and_spec_agree_on_distribution_when_aligned() {
    // With target == draft and window covering everything, a single
    // speculative outer loop samples the full factorized distribution in
    // one pass — the same distribution MDM with K=1 samples. Check the
    // per-position marginals roughly agree.
    let d = 6;
    let v = 3;
    let mut m = MockModel::new(d, v, 77);
    m.target_equals_draft = true;
    let spec = SpecParams {
        window: Window::Constant(d),
        n_verify: 1,
        ..Default::default()
    };
    let mdm = MdmParams { steps: 1, temperature: 1.0 };
    let n = 4000;
    let mut counts_spec = vec![0usize; d * v];
    let mut counts_mdm = vec![0usize; d * v];
    let mut rng = Pcg::new(1);
    for _ in 0..n {
        let (s, _) = ssmd::engine::speculative_sample(
            &m, &[Prompt::empty(d)], &spec, &mut rng);
        for (pos, &t) in s[0].tokens.iter().enumerate() {
            counts_spec[pos * v + t as usize] += 1;
        }
        let s = ssmd::engine::mdm_sample(&m, &[Prompt::empty(d)], &mdm,
                                         &mut rng);
        for (pos, &t) in s[0].tokens.iter().enumerate() {
            counts_mdm[pos * v + t as usize] += 1;
        }
    }
    for i in 0..d * v {
        let a = counts_spec[i] as f64 / n as f64;
        let b = counts_mdm[i] as f64 / n as f64;
        assert!((a - b).abs() < 0.05, "marginal {i}: {a} vs {b}");
    }
}
