//! JAX <-> rust-runtime numerical parity.
//!
//! `python/compile/aot.py` embeds a golden record per model in the
//! manifest: a deterministic input and the JAX-computed outputs (logit
//! head slices + means). This test replays the same input through the
//! compiled HLO via PJRT and checks the numbers to f32 tolerance — the
//! core guarantee that the serving path computes the same function the
//! model was trained as.
//!
//! Skips silently when artifacts are absent (pre-`make artifacts` builds).

use ssmd::engine::HybridModel;
use ssmd::runtime::{Manifest, Runtime};
use ssmd::util::json::Json;

const ATOL: f64 = 2e-4;

fn artifacts_dir() -> Option<String> {
    let dir =
        std::env::var("SSMD_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&dir)
        .join("manifest.json")
        .exists()
        .then_some(dir)
}

fn close(a: f64, b: f64, what: &str) {
    assert!(
        (a - b).abs() < ATOL * (1.0 + b.abs()),
        "{what}: rust {a} vs jax {b}"
    );
}

#[test]
fn golden_outputs_match_jax() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("pjrt_parity skipped: no artifacts");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let raw = std::fs::read_to_string(
        std::path::Path::new(&dir).join("manifest.json"),
    )
    .unwrap();
    let manifest_json = Json::parse(&raw).unwrap();
    let runtime = Runtime::cpu().unwrap();

    let mut checked = 0;
    for (name, entry) in &manifest.models {
        let Some(golden) = manifest_json
            .get("models")
            .and_then(|m| m.get(name))
            .and_then(|m| m.get("golden"))
        else {
            continue;
        };
        let model = runtime.load_model(entry).unwrap();
        let d = model.seq_len();
        let v = model.vocab();
        let bucket = model.buckets().into_iter().min().unwrap();

        // ---- draft parity -------------------------------------------------
        let tokens_row: Vec<i32> = golden
            .get("tokens")
            .and_then(|t| t.as_f64_vec())
            .unwrap()
            .into_iter()
            .map(|x| x as i32)
            .collect();
        assert_eq!(tokens_row.len(), d);
        let tokens: Vec<i32> = (0..bucket)
            .flat_map(|_| tokens_row.iter().copied())
            .collect();
        let (state, logits) = model.draft(&tokens, bucket);
        let head = golden
            .get("draft_logits_head")
            .and_then(|h| h.as_f64_vec())
            .unwrap();
        for (i, expect) in head.iter().enumerate() {
            close(logits[i] as f64, *expect,
                  &format!("{name} draft logit {i}"));
        }
        let row0_mean = logits[..d * v]
            .iter()
            .map(|&x| x as f64)
            .sum::<f64>()
            / (d * v) as f64;
        close(
            row0_mean,
            golden.get("draft_logits_mean").unwrap().as_f64().unwrap(),
            &format!("{name} draft mean"),
        );

        // ---- verify parity ------------------------------------------------
        if let Some(full) = golden.get("full_tokens") {
            let full_row: Vec<i32> = full
                .as_f64_vec()
                .unwrap()
                .into_iter()
                .map(|x| x as i32)
                .collect();
            let sigma_row: Vec<i32> = golden
                .get("sigma")
                .and_then(|s| s.as_f64_vec())
                .unwrap()
                .into_iter()
                .map(|x| x as i32)
                .collect();
            let full: Vec<i32> = (0..bucket)
                .flat_map(|_| full_row.iter().copied())
                .collect();
            let sigma: Vec<i32> = (0..bucket)
                .flat_map(|_| sigma_row.iter().copied())
                .collect();
            let tlogits = model.verify(&state, &full, &sigma, bucket);
            let head = golden
                .get("target_logits_head")
                .and_then(|h| h.as_f64_vec())
                .unwrap();
            for (i, expect) in head.iter().enumerate() {
                close(tlogits[i] as f64, *expect,
                      &format!("{name} target logit {i}"));
            }
            let mean0 = tlogits[..d * v]
                .iter()
                .map(|&x| x as f64)
                .sum::<f64>()
                / (d * v) as f64;
            close(
                mean0,
                golden.get("target_logits_mean").unwrap().as_f64().unwrap(),
                &format!("{name} target mean"),
            );
        }
        checked += 1;
        eprintln!("parity ok: {name}");
    }
    assert!(checked > 0, "no golden records found in manifest");
}

#[test]
fn buckets_agree_with_each_other() {
    // The same row must produce the same outputs regardless of which
    // bucket executes it (padding rows must not leak).
    let Some(dir) = artifacts_dir() else {
        eprintln!("bucket test skipped: no artifacts");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let runtime = Runtime::cpu().unwrap();
    let Some(entry) = manifest.models.get("owt") else {
        return;
    };
    if entry.buckets.len() < 2 {
        return;
    }
    let model = runtime.load_model(entry).unwrap();
    let d = model.seq_len();
    let v = model.vocab();
    let row: Vec<i32> = (0..d as i32).map(|i| (i * 5) % v as i32).collect();
    let b0 = entry.buckets[0];
    let b1 = entry.buckets[1];
    let t0: Vec<i32> = (0..b0).flat_map(|_| row.iter().copied()).collect();
    let t1: Vec<i32> = (0..b1).flat_map(|_| row.iter().copied()).collect();
    let (_, l0) = model.draft(&t0, b0);
    let (_, l1) = model.draft(&t1, b1);
    for i in 0..d * v {
        assert!(
            (l0[i] - l1[i]).abs() < 1e-4,
            "bucket outputs diverge at {i}: {} vs {}",
            l0[i],
            l1[i]
        );
    }
}
