//! Deterministic virtual-time simulation of the cross-queue scheduler.
//!
//! The harness itself lives in `ssmd::sim` (promoted to the library in
//! PR 5 so `examples/trace_replay.rs` can replay *recorded* traces
//! through it); this file holds the assertions:
//!
//! * the PR-3 headline — on a mixed workload (bulk queue at 10x the
//!   request arrival rate of a small SLO queue) the SLO queue's simulated
//!   p95 queue wait under the weighted scheduler is strictly lower than
//!   under round-robin, and an all-one-queue trace shows zero throughput
//!   loss vs round-robin;
//! * the PR-5 headline — on a bulk-saturated trace with an SLO-queue
//!   spike, **preemptive** scheduling (mid-sequence checkpoint/evict/
//!   resume) gives strictly lower SLO-queue p95 than weighted
//!   non-preemptive scheduling, while every preempted sequence's token
//!   stream stays **bitwise identical** to the same-seed unpreempted
//!   run;
//! * trace record -> replay: the JSONL round-trip reproduces identical
//!   step/shed/violation counters across replays;
//! * scheduler invariants under randomized traces (seeded PCG, many
//!   seeds): no sequence lost or double-answered, no non-empty queue
//!   starves beyond a bounded number of rounds, weighted step shares of
//!   backlogged queues converge to the configured ratios;
//! * admission backpressure: shed-vs-queue accounting stays conservative
//!   at both granularities (requests and sequences);
//! * the PR-7 chaos pins — fault-plan replay in virtual time: a fatal
//!   injected fault quarantines only its own queue while conservation
//!   holds (every admitted sequence is finished, failed, or deadline-
//!   shed, exactly once) and the surviving queue's token streams are
//!   **bitwise identical** to a fault-free run; transient faults retry
//!   with backoff and recover exactly; the circuit breaker opens, fast-
//!   fails admissions through its cooldown, and closes on a half-open
//!   probe; deadline expiry is swept and counted separately from
//!   backpressure sheds; chaos traces round-trip through JSONL and
//!   replay bit-identically.

use ssmd::coordinator::sched::{QueuePolicy, SchedConfig};
use ssmd::engine::FaultPlan;
use ssmd::sim::{mean, p95, read_trace, simulate, write_trace, Arrival,
                QueueSpec, Report, Selector};
use ssmd::util::ptest::{self, Size};
use ssmd::util::rng::Pcg;

/// Headline mixed workload: a bulk queue taking 10 requests/s against a
/// small SLO queue taking 1 request/s (bursts of 4 short sequences).
fn headline_setup() -> (Vec<QueueSpec>, Vec<Arrival>) {
    let specs = vec![
        // Bulk: GPT2-scale stand-in — big batches, expensive steps.
        QueueSpec::new(16, 4, 0.08, QueuePolicy {
            weight: 1.0,
            ..QueuePolicy::default()
        }),
        // SLO: small-vocab latency queue — cheap steps, weighted 4x with
        // a 50ms p95 target and a burst bound wide enough to drain a
        // whole burst between bulk steps.
        QueueSpec::new(12, 1, 0.01, QueuePolicy {
            weight: 4.0,
            slo_p95_s: Some(0.05),
            max_consecutive: 16,
            ..QueuePolicy::default()
        }),
    ];
    let mut trace = Vec::new();
    for k in 0..60 {
        trace.push(Arrival {
            t: 0.1 * k as f64,
            queue: 0,
            n: 1,
            seed: 1000 + k,
            ..Arrival::default()
        });
    }
    for k in 0..5 {
        trace.push(Arrival {
            t: 0.05 + k as f64,
            queue: 1,
            n: 4,
            seed: 2000 + k,
            ..Arrival::default()
        });
    }
    trace.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    (specs, trace)
}

#[test]
fn weighted_beats_round_robin_on_slo_queue_p95() {
    let (specs, trace) = headline_setup();
    let cfg = SchedConfig::default();
    let rr = simulate(&specs, &trace, Selector::RoundRobin, &cfg);
    let w = simulate(&specs, &trace, Selector::Weighted, &cfg);
    // Both selectors serve everything.
    assert_eq!(rr.finished, vec![60, 20]);
    assert_eq!(w.finished, vec![60, 20]);
    let (p95_rr, p95_w) = (p95(&rr.waits[1]), p95(&w.waits[1]));
    assert!(
        p95_w < p95_rr,
        "weighted p95 {p95_w:.3}s must beat round-robin {p95_rr:.3}s"
    );
    // The gap is structural, not marginal: bursts drain ~4x faster.
    assert!(
        p95_w < 0.5 * p95_rr,
        "weighted p95 {p95_w:.3}s vs RR {p95_rr:.3}s: gap collapsed"
    );
    assert!(mean(&w.waits[1]) < mean(&rr.waits[1]));
    // The early burst placements exceeded the 50ms SLO before the boost
    // kicked in, so violations were observed and counted.
    assert!(w.slo_violations >= 1);
    // The bulk queue still drains with bounded starvation.
    assert!(w.max_starve <= cfg.starve_after + specs.len() as u64);
    // No preemption was configured, so none may fire.
    assert_eq!(w.preemptions, 0);
    assert_eq!(w.preempt_fires, 0);
}

// ---------------------------------------------------------------------------
// Preemptive serving headline
// ---------------------------------------------------------------------------

/// Bulk-saturated trace with an SLO-queue spike: the bulk queue carries
/// ~51s of step work admitted in the first second; at t = 2.0 the SLO
/// queue takes a 10-sequence spike whose waits blow its 5ms target far
/// past the boost ceiling.
fn preempt_setup(preempt: bool) -> (Vec<QueueSpec>, Vec<Arrival>) {
    let specs = vec![
        QueueSpec::new(16, 4, 0.08, QueuePolicy {
            weight: 1.0,
            preempt,
            ..QueuePolicy::default()
        }),
        QueueSpec::new(8, 1, 0.004, QueuePolicy {
            weight: 4.0,
            slo_p95_s: Some(0.005),
            // Without preemption the burst bound forces a 0.08s bulk
            // step into every 8 SLO steps — the structural latency the
            // preemptive run removes.
            max_consecutive: 8,
            ..QueuePolicy::default()
        }),
    ];
    let mut trace = Vec::new();
    for k in 0..20 {
        trace.push(Arrival {
            t: 0.05 * k as f64,
            queue: 0,
            n: 2,
            seed: 1000 + k,
            ..Arrival::default()
        });
    }
    for k in 0..10u64 {
        trace.push(Arrival {
            t: 2.0 + 0.001 * k as f64,
            queue: 1,
            n: 1,
            seed: 2000 + k,
            priority: 1,
            ..Arrival::default()
        });
    }
    trace.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    (specs, trace)
}

/// The PR-5 headline: preemption (park the saturated bulk queue's
/// residents mid-sequence while the SLO spike drains, resume after)
/// strictly beats weighted non-preemptive scheduling on SLO p95 — and
/// checkpoint/resume is bitwise exact, so the bulk queue pays only
/// *time*, never different tokens.
#[test]
fn preemption_beats_weighted_on_slo_spike_and_is_bitwise_exact() {
    let cfg = SchedConfig { preempt_after: 2, ..SchedConfig::default() };
    let (specs_pre, trace) = preempt_setup(true);
    let (specs_plain, trace_plain) = preempt_setup(false);
    let pre = simulate(&specs_pre, &trace, Selector::Weighted, &cfg);
    let plain =
        simulate(&specs_plain, &trace_plain, Selector::Weighted, &cfg);

    // Conservation on both: 40 bulk + 10 SLO sequences served.
    assert_eq!(pre.finished, vec![40, 10]);
    assert_eq!(plain.finished, vec![40, 10]);

    // Preemption actually happened (and resumed everything it parked).
    assert!(pre.preempt_fires >= 1, "preemption never fired");
    assert!(pre.preemptions >= 1);
    assert_eq!(pre.preemptions, pre.resumes,
               "every evicted sequence must be resumed exactly once");
    assert_eq!(plain.preemptions, 0);

    // Strictly lower SLO-queue p95 — the headline.
    let (p95_pre, p95_plain) = (p95(&pre.waits[1]), p95(&plain.waits[1]));
    assert!(
        p95_pre < p95_plain,
        "preemptive p95 {p95_pre:.3}s must beat non-preemptive \
         {p95_plain:.3}s"
    );
    assert!(
        p95_pre < 0.75 * p95_plain,
        "preemptive p95 {p95_pre:.3}s vs {p95_plain:.3}s: gap collapsed"
    );
    assert!(mean(&pre.waits[1]) < mean(&plain.waits[1]));

    // Bitwise checkpoint/resume determinism: every sequence — the
    // preempted bulk residents included — produced the exact token
    // stream of the unpreempted run (same seeds, same SlotIds).
    assert_eq!(pre.tokens[0], plain.tokens[0],
               "preempted bulk token streams diverged");
    assert_eq!(pre.tokens[1], plain.tokens[1]);
}

/// `SchedConfig::checkpoint_budget` wired through the engine-mirroring
/// harness: redo work parked by each preemption is charged against the
/// victim's budget, and a zero budget marks every victim exhausted from
/// the start — SLO pressure can then never fire a preemption. Since
/// checkpoint/resume is bitwise-free, turning the knob changes timing
/// only, never tokens.
#[test]
fn checkpoint_budget_caps_preemption_without_token_drift() {
    let (specs, trace) = preempt_setup(true);
    let open = SchedConfig { preempt_after: 2, ..SchedConfig::default() };
    let zero = SchedConfig {
        preempt_after: 2,
        checkpoint_budget: 0,
        ..SchedConfig::default()
    };
    let pre = simulate(&specs, &trace, Selector::Weighted, &open);
    let off = simulate(&specs, &trace, Selector::Weighted, &zero);
    assert!(pre.preempt_fires >= 1, "default budget must let fires through");
    assert_eq!(off.preempt_fires, 0,
               "zero budget must retire every victim before the first fire");
    assert_eq!(off.preemptions, 0);
    // Conservation and bitwise determinism hold on both settings.
    assert_eq!(off.finished, vec![40, 10]);
    assert_eq!(pre.tokens, off.tokens,
               "checkpoint budget changed a token stream");
}

#[test]
fn all_one_queue_trace_loses_no_throughput() {
    // Adversarial trace: every arrival targets one queue. The weighted
    // selector must degenerate to exactly the round-robin behavior —
    // identical step count, identical drain time, identical waits.
    let specs = vec![QueueSpec::new(12, 2, 0.02, QueuePolicy {
        weight: 3.0,
        slo_p95_s: Some(0.01),
        ..QueuePolicy::default()
    })];
    let mut trace = Vec::new();
    for k in 0..12 {
        trace.push(Arrival {
            t: 0.05 * k as f64,
            queue: 0,
            n: 1 + (k as usize % 3),
            seed: 300 + k,
            ..Arrival::default()
        });
    }
    let cfg = SchedConfig::default();
    let rr = simulate(&specs, &trace, Selector::RoundRobin, &cfg);
    let w = simulate(&specs, &trace, Selector::Weighted, &cfg);
    assert_eq!(w.steps, rr.steps, "weighted ran extra steps");
    assert_eq!(w.t_end, rr.t_end, "weighted lost throughput");
    assert_eq!(w.waits, rr.waits, "weighted changed single-queue waits");
    assert_eq!(w.finished, rr.finished);
}

#[test]
fn simulation_is_deterministic() {
    let (specs, trace) = headline_setup();
    let cfg = SchedConfig::default();
    let a = simulate(&specs, &trace, Selector::Weighted, &cfg);
    let b = simulate(&specs, &trace, Selector::Weighted, &cfg);
    assert_eq!(a, b, "virtual-time simulation must be bit-reproducible");
}

/// Trace record -> replay round-trip: writing the preemptive headline
/// scenario to JSONL, reading it back, and replaying must reproduce the
/// direct run's counters exactly — and two replays of the same file must
/// be bitwise identical (the CI smoke-trace gate relies on this).
#[test]
fn trace_roundtrip_replays_identical_counters() {
    let cfg = SchedConfig { preempt_after: 2, ..SchedConfig::default() };
    let (specs, trace) = preempt_setup(true);
    let direct = simulate(&specs, &trace, Selector::Weighted, &cfg);
    let path = std::env::temp_dir()
        .join(format!("ssmd_sched_sim_rt_{}.jsonl", std::process::id()));
    write_trace(&path, &cfg, &specs, &trace).unwrap();
    let (cfg2, specs2, trace2, _) = read_trace(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let replay_a = simulate(&specs2, &trace2, Selector::Weighted, &cfg2);
    let replay_b = simulate(&specs2, &trace2, Selector::Weighted, &cfg2);
    assert_eq!(replay_a, replay_b, "two replays must be bit-identical");
    assert_eq!(replay_a, direct,
               "replay through the JSONL round-trip must reproduce the \
                direct run (steps/sheds/violations/tokens included)");
}

#[test]
fn shed_policy_is_conservative_and_queue_policy_admits_all() {
    // 20 single-sequence requests land at t=0 on a depth-5 queue.
    let shed_spec = vec![QueueSpec::new(8, 1, 0.01, QueuePolicy {
        max_pending: 5,
        shed_on_full: true,
        ..QueuePolicy::default()
    })];
    let trace: Vec<Arrival> = (0..20)
        .map(|k| Arrival { t: 0.0, queue: 0, n: 1, seed: 50 + k,
                           ..Arrival::default() })
        .collect();
    let cfg = SchedConfig::default();
    let r = simulate(&shed_spec, &trace, Selector::Weighted, &cfg);
    assert_eq!(r.shed, 15, "depth-5 bound must shed 15 of 20");
    // Single-sequence requests: the request and sequence denominators
    // coincide here; multi-sequence sheds are pinned in sched's units.
    assert_eq!(r.shed_requests, 15);
    assert_eq!(r.finished[0], 5);
    // Same trace under queue-on-full: everything is admitted and served.
    let queue_spec = vec![QueueSpec::new(8, 1, 0.01, QueuePolicy {
        max_pending: 5,
        shed_on_full: false,
        ..QueuePolicy::default()
    })];
    let r = simulate(&queue_spec, &trace, Selector::Weighted, &cfg);
    assert_eq!(r.shed, 0);
    assert_eq!(r.shed_requests, 0);
    assert_eq!(r.finished[0], 20);
}

/// Priority-aware shedding: over a full queue, the lowest-priority
/// pending request is displaced by a strictly higher-priority arrival
/// (instead of the arrival being refused FIFO-blind), while an arrival
/// of equal priority is still turned away — and the survivor's token
/// streams are untouched by the displacement.
#[test]
fn priority_shed_displaces_lowest_class_first() {
    let specs = vec![QueueSpec::new(8, 1, 0.01, QueuePolicy {
        max_pending: 2,
        shed_on_full: true,
        ..QueuePolicy::default()
    })];
    let trace = vec![
        // Low-priority request fills the queue first.
        Arrival { t: 0.0, queue: 0, n: 2, seed: 1, priority: -1,
                  ..Arrival::default() },
        // Strictly higher-priority arrival: displaces the whole
        // low-priority request rather than being refused.
        Arrival { t: 0.0, queue: 0, n: 2, seed: 2, priority: 0,
                  ..Arrival::default() },
        // Equal priority to the survivor: refused at the door (no
        // strictly-lower victim remains).
        Arrival { t: 0.0, queue: 0, n: 1, seed: 3, priority: 0,
                  ..Arrival::default() },
    ];
    let cfg = SchedConfig::default();
    let r = simulate(&specs, &trace, Selector::Weighted, &cfg);
    assert_eq!(r.finished[0], 2, "only the high-priority request runs");
    assert_eq!(r.shed, 3,
               "2 displaced victim sequences + 1 refused equal-priority");
    assert_eq!(r.shed_requests, 2, "one displaced + one refused request");
    // The survivor's streams are exactly what a lone run produces
    // (slot ids differ with admission order, so compare streams).
    let lone = simulate(&specs,
                        &[Arrival { t: 0.0, queue: 0, n: 2, seed: 2,
                                    priority: 0, ..Arrival::default() }],
                        Selector::Weighted, &cfg);
    let mut got: Vec<Vec<i32>> = r.tokens[0].values().cloned().collect();
    let mut want: Vec<Vec<i32>> =
        lone.tokens[0].values().cloned().collect();
    got.sort();
    want.sort();
    assert_eq!(got, want,
               "displacement must not perturb the survivor's tokens");
}

/// A multi-sequence shed keeps the two denominators distinct end-to-end:
/// one request of 4 sequences refused = 1 request / 4 sequences.
#[test]
fn shed_counters_distinguish_requests_from_sequences() {
    let specs = vec![QueueSpec::new(8, 1, 0.01, QueuePolicy {
        max_pending: 2,
        shed_on_full: true,
        ..QueuePolicy::default()
    })];
    let trace = vec![
        Arrival { t: 0.0, queue: 0, n: 2, seed: 1, ..Arrival::default() },
        Arrival { t: 0.0, queue: 0, n: 4, seed: 2, ..Arrival::default() },
    ];
    let r = simulate(&specs, &trace, Selector::Weighted,
                     &SchedConfig::default());
    assert_eq!(r.shed, 4, "4 sequences refused");
    assert_eq!(r.shed_requests, 1, "1 request refused");
    assert_eq!(r.finished[0], 2);
}

// ---------------------------------------------------------------------------
// Chaos: fault-plan replay in virtual time (PR 7)
// ---------------------------------------------------------------------------

/// Two-queue chaos scenario: queue 1 carries the fault plan, queue 0 is
/// the innocent bystander whose streams must survive untouched.
fn chaos_setup(fault: Option<&str>) -> (Vec<QueueSpec>, Vec<Arrival>) {
    let mut specs = vec![
        QueueSpec::new(8, 2, 0.01, QueuePolicy::default()),
        QueueSpec::new(8, 2, 0.02, QueuePolicy::default()),
    ];
    specs[1].model_seed = 11;
    specs[1].fault = fault.map(|f| FaultPlan::parse(f).unwrap());
    let mut trace = Vec::new();
    for k in 0..6u64 {
        trace.push(Arrival {
            t: 0.05 * k as f64,
            queue: (k % 2) as usize,
            n: 2,
            seed: 400 + k,
            ..Arrival::default()
        });
    }
    (specs, trace)
}

/// The tentpole pin: a fatal injected fault quarantines only its own
/// queue — conservation holds (every admitted sequence is finished or
/// failed, never lost) and the surviving queue's token streams are
/// **bitwise identical** to a fault-free run of the same trace.
#[test]
fn chaos_fatal_fault_conserves_and_keeps_survivors_bitwise_identical() {
    let cfg = SchedConfig::default();
    let (clean_specs, trace) = chaos_setup(None);
    let clean = simulate(&clean_specs, &trace, Selector::Weighted, &cfg);
    // panic@3: the third model call of queue 1 unwinds — a genuine panic
    // through BoundStepper's catch_unwind, classified fatal.
    let (specs, trace2) = chaos_setup(Some("panic@3"));
    let r = simulate(&specs, &trace2, Selector::Weighted, &cfg);
    assert_eq!(r.engine_faults, 1, "exactly one definitive fault");
    assert!(r.failed[1] >= 1, "queue 1 must report failed sequences");
    assert_eq!(r.failed[0], 0, "queue 0 must be untouched");
    // Conservation across outcomes (also asserted inside simulate()).
    assert_eq!(r.finished[1] + r.failed[1], 6,
               "queue 1: finished + failed must cover all admitted");
    assert_eq!(r.finished[0], 6);
    // Bitwise-identical survivors: same SlotIds, same token streams.
    assert_eq!(r.tokens[0], clean.tokens[0],
               "surviving queue's streams diverged under chaos");
}

/// Transient faults (InjectedErr unwinds) retry with virtual-time
/// backoff and recover: nothing fails, the retry is counted, and the
/// drain takes at least the backoff longer than the fault-free run.
#[test]
fn chaos_transient_fault_retries_and_recovers_in_virtual_time() {
    let cfg = SchedConfig::default();
    let (clean_specs, trace) = chaos_setup(None);
    let clean = simulate(&clean_specs, &trace, Selector::Weighted, &cfg);
    let (specs, trace2) = chaos_setup(Some("err@3"));
    let r = simulate(&specs, &trace2, Selector::Weighted, &cfg);
    assert_eq!(r.retries, 1);
    assert_eq!(r.engine_faults, 0, "recovered burst is not definitive");
    assert_eq!(r.failed, vec![0, 0]);
    assert_eq!(r.finished, vec![6, 6], "everything still finishes");
    // The failed step still charged its virtual cost (the backoff window
    // itself may be absorbed by the other queue's work, since the global
    // clock only advances on executed steps).
    assert!(r.t_end > clean.t_end + 1e-9,
            "the aborted step must cost virtual time: {} vs {}",
            r.t_end, clean.t_end);
    // Recovery is exact, not just complete: token streams match the
    // fault-free run on both queues.
    assert_eq!(r.tokens, clean.tokens);
}

/// Breaker lifecycle in virtual time: a hair-trigger breaker opens on
/// the first definitive fault, fast-fails admissions during cooldown,
/// then half-opens and closes on a successful probe.
#[test]
fn chaos_breaker_opens_sheds_then_half_open_probe_recovers() {
    let mut cfg = SchedConfig::default();
    cfg.supervise.breaker_threshold = 1;
    cfg.supervise.breaker_cooldown_s = 5.0;
    let mut specs = vec![QueueSpec::new(8, 1, 0.01,
                                        QueuePolicy::default())];
    specs[0].fault = Some(FaultPlan::parse("panic@1").unwrap());
    let trace = vec![
        // Trips the breaker (fault fires on the very first model call).
        Arrival { t: 0.0, queue: 0, n: 1, seed: 1,
                  ..Arrival::default() },
        // Lands inside the cooldown window: fast-failed, never queued.
        Arrival { t: 1.0, queue: 0, n: 2, seed: 2,
                  ..Arrival::default() },
        // Lands after cooldown: the half-open probe; the plan is spent,
        // so it succeeds and closes the breaker.
        Arrival { t: 10.0, queue: 0, n: 1, seed: 3,
                  ..Arrival::default() },
    ];
    let r = simulate(&specs, &trace, Selector::Weighted, &cfg);
    assert_eq!(r.engine_faults, 1);
    assert_eq!(r.breaker_opens, 1, "exactly one Closed->Open transition");
    assert_eq!(r.breaker_shed, 2, "cooldown admissions fast-fail");
    assert_eq!(r.failed[0], 1, "the tripping sequence is answered failed");
    assert_eq!(r.finished[0], 1, "the probe request completes");
    assert_eq!(r.shed, 0, "breaker sheds are not backpressure sheds");
}

/// Deadline expiry in virtual time: an injected stall pushes a deadlined
/// sequence past its budget; the sweep removes exactly that sequence and
/// counts it in `deadline_sheds`, while undeadlined work completes.
#[test]
fn chaos_deadline_expiry_is_swept_and_counted() {
    let mut specs = vec![QueueSpec::new(8, 1, 0.01,
                                        QueuePolicy::default())];
    specs[0].fault = Some(FaultPlan::parse("stall@1:1.0").unwrap());
    let trace = vec![
        Arrival { t: 0.0, queue: 0, n: 1, seed: 1, deadline: Some(0.5),
                  ..Arrival::default() },
        Arrival { t: 0.0, queue: 0, n: 1, seed: 2,
                  ..Arrival::default() },
    ];
    let r = simulate(&specs, &trace, Selector::Weighted,
                     &SchedConfig::default());
    assert_eq!(r.deadline_sheds, 1,
               "the 0.5s-deadline sequence dies to the 1s stall");
    assert_eq!(r.finished[0], 1, "the undeadlined sequence completes");
    assert_eq!(r.failed[0], 0);
    assert_eq!(r.engine_faults, 0, "a stall is latency, not a fault");
    assert_eq!(r.shed, 0,
               "deadline sheds are distinct from backpressure sheds");
}

/// Chaos replay determinism: a trace carrying fault plans and deadlines
/// round-trips through JSONL and replays bit-identically — the CI
/// chaos-smoke gate relies on exactly this.
#[test]
fn chaos_trace_roundtrip_replays_identical_reports() {
    let mut cfg = SchedConfig::default();
    cfg.supervise.breaker_threshold = 1;
    cfg.supervise.breaker_cooldown_s = 2.0;
    let (mut specs, mut trace) = chaos_setup(Some("err@2,panic@7"));
    trace.push(Arrival { t: 0.4, queue: 1, n: 1, seed: 900,
                         deadline: Some(0.05), ..Arrival::default() });
    specs[0].policy.max_pending = 64;
    let direct = simulate(&specs, &trace, Selector::Weighted, &cfg);
    let path = std::env::temp_dir()
        .join(format!("ssmd_chaos_rt_{}.jsonl", std::process::id()));
    write_trace(&path, &cfg, &specs, &trace).unwrap();
    let (cfg2, specs2, trace2, _) = read_trace(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(cfg2.supervise.breaker_threshold, 1);
    assert_eq!(cfg2.supervise.breaker_cooldown_s, 2.0);
    let replay_a = simulate(&specs2, &trace2, Selector::Weighted, &cfg2);
    let replay_b = simulate(&specs2, &trace2, Selector::Weighted, &cfg2);
    assert_eq!(replay_a, replay_b, "chaos replays must be bit-identical");
    assert_eq!(replay_a, direct,
               "chaos replay through JSONL must reproduce the direct run \
                (faults, deadlines, breaker counters included)");
    // The scenario actually exercised the failure layer.
    assert!(direct.retries >= 1 || direct.engine_faults >= 1);
}

// ---------------------------------------------------------------------------
// Property tests: randomized admission traces, many seeds
// ---------------------------------------------------------------------------

/// Random trace generator: three adversarial shapes — bursty clusters,
/// heavy-tailed (Pareto-ish) inter-arrivals, and all-one-queue floods.
fn random_case(rng: &mut Pcg, s: Size)
               -> (Vec<QueueSpec>, Vec<Arrival>, u64) {
    let nq = 2 + rng.below(3);
    let specs: Vec<QueueSpec> = (0..nq)
        .map(|_| {
            let policy = QueuePolicy {
                weight: 0.5 + rng.f64() * 3.5,
                slo_p95_s: if rng.below(2) == 0 {
                    Some(0.02 + rng.f64() * 0.2)
                } else {
                    None
                },
                ..QueuePolicy::default()
            };
            QueueSpec {
                d: 8,
                vocab: 4 + rng.below(4),
                bucket: 1 + rng.below(2),
                model_seed: rng.next_u64(),
                policy,
                step_cost: 0.005 + rng.f64() * 0.045,
                fault: None,
            }
        })
        .collect();
    let shape = rng.below(3);
    let n_arrivals = 8 + (s.0 * 3).min(16);
    let mut t = 0.0;
    let mut trace = Vec::new();
    for _ in 0..n_arrivals {
        match shape {
            // Bursty: arrivals cluster at shared instants.
            0 => {
                if rng.below(3) == 0 {
                    t += rng.f64() * 0.6;
                }
            }
            // Heavy-tailed inter-arrivals: mostly tiny gaps, rare big
            // ones (t += 0.01 * u^-0.7, capped).
            1 => {
                let u = rng.f64().max(1e-6);
                t += (0.01 * u.powf(-0.7)).min(2.0);
            }
            // Adversarial: everything lands at once.
            _ => {}
        }
        let queue = if shape == 2 { 0 } else { rng.below(nq) };
        trace.push(Arrival {
            t,
            queue,
            n: 1 + rng.below(4),
            seed: rng.next_u64(),
            priority: rng.below(3) as i32 - 1,
            ..Arrival::default()
        });
    }
    (specs, trace, rng.next_u64())
}

#[test]
fn property_no_loss_no_double_answer_bounded_starvation() {
    let cfg = SchedConfig { starve_after: 16, ..SchedConfig::default() };
    ptest::check(
        10,
        0x5eed_51,
        random_case,
        |(specs, trace, _)| {
            let r = simulate(specs, trace, Selector::Weighted, &cfg);
            // Conservation is asserted inside simulate(); cross-check the
            // totals against the trace minus sheds here.
            let admitted: usize =
                trace.iter().map(|a| a.n).sum::<usize>()
                    - r.shed as usize;
            let served: usize = r.finished.iter().sum();
            if served != admitted {
                return Err(format!(
                    "served {served} != admitted {admitted}"
                ));
            }
            // Starvation bound: starve_after plus one round per ready
            // queue (simultaneously-starved queues drain one per round).
            let bound = cfg.starve_after + specs.len() as u64;
            if r.max_starve > bound {
                return Err(format!(
                    "starve streak {} exceeds bound {bound}",
                    r.max_starve
                ));
            }
            Ok(())
        },
    );
}

/// Same conservation/starvation properties with preemption armed on
/// every queue: parking/resuming under random adversarial traffic must
/// not lose, duplicate, or (because parked queues are deliberately
/// paused, not starved) trip the starvation accounting.
#[test]
fn property_preemption_conserves_under_random_traces() {
    let cfg = SchedConfig {
        starve_after: 16,
        preempt_after: 2,
        ..SchedConfig::default()
    };
    ptest::check(
        8,
        0x5eed_52,
        |rng: &mut Pcg, s: Size| {
            let (mut specs, trace, seed) = random_case(rng, s);
            for q in specs.iter_mut() {
                q.policy.preempt = true;
                // Tight SLOs on the SLO-carrying queues so pressure
                // actually reaches the ceiling under bursts.
                if let Some(slo) = q.policy.slo_p95_s {
                    q.policy.slo_p95_s = Some(slo / 100.0);
                }
            }
            (specs, trace, seed)
        },
        |(specs, trace, _)| {
            let r = simulate(specs, trace, Selector::Weighted, &cfg);
            let admitted: usize =
                trace.iter().map(|a| a.n).sum::<usize>()
                    - r.shed as usize;
            let served: usize = r.finished.iter().sum();
            if served != admitted {
                return Err(format!(
                    "served {served} != admitted {admitted} \
                     (preemptions {}, resumes {})",
                    r.preemptions, r.resumes
                ));
            }
            if r.preemptions != r.resumes {
                return Err(format!(
                    "evicted {} != resumed {}",
                    r.preemptions, r.resumes
                ));
            }
            let bound = cfg.starve_after + specs.len() as u64;
            if r.max_starve > bound {
                return Err(format!(
                    "starve streak {} exceeds bound {bound}",
                    r.max_starve
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_backlogged_step_shares_converge_to_weights() {
    // All queues share identical per-step costs and carry deep backlogs;
    // sequence work is exact (a Constant(1) window decides one position
    // per outer loop, so every sequence costs exactly d steps), so the
    // busy-window step shares must track the weight ratios closely.
    ptest::check(
        8,
        0x5a4e_5,
        |rng: &mut Pcg, _s: Size| {
            let nq = 2 + rng.below(2);
            let weights: Vec<f64> =
                (0..nq).map(|_| 1.0 + rng.f64() * 3.0).collect();
            (nq, weights, rng.next_u64())
        },
        |(nq, weights, seed)| {
            let specs: Vec<QueueSpec> = weights
                .iter()
                .map(|&w| {
                    QueueSpec::new(8, 1, 0.01, QueuePolicy {
                        weight: w,
                        // Shares, not burst shaping, are under test.
                        max_consecutive: u32::MAX,
                        ..QueuePolicy::default()
                    })
                })
                .collect();
            // Deep backlog for every queue, all admitted at t = 0.
            let trace: Vec<Arrival> = (0..*nq)
                .map(|i| Arrival {
                    t: 0.0,
                    queue: i,
                    n: 40,
                    seed: seed ^ i as u64,
                    ..Arrival::default()
                })
                .collect();
            let r: Report = simulate(&specs, &trace, Selector::Weighted,
                                     &SchedConfig::default());
            let total: u64 = r.busy_steps.iter().sum();
            let wsum: f64 = weights.iter().sum();
            for i in 0..*nq {
                let got = r.busy_steps[i] as f64 / total as f64;
                let want = weights[i] / wsum;
                if (got - want).abs() > 0.25 * want {
                    return Err(format!(
                        "queue {i}: step share {got:.3} vs weight share \
                         {want:.3} (busy {:?})",
                        r.busy_steps
                    ));
                }
            }
            Ok(())
        },
    );
}
