//! Deterministic virtual-time simulation of the cross-queue scheduler.
//!
//! The weighted SLO-aware selector (`coordinator::sched`) is pure state
//! driven by an injected `Clock`, so this harness can replay scripted
//! multi-queue arrival traces against real `BoundStepper`/`MockModel`
//! steppers with **synthetic per-step costs** on a `SimClock` — every
//! latency and fairness number below is exact: no sleeps, no wall time,
//! no flakiness. The round-robin baseline (the pre-weighted engine-loop
//! policy) runs in the same harness, so weighted-vs-RR comparisons hold
//! everything else fixed.
//!
//! Sequences use a `Constant(1)` accept window, which decides exactly one
//! ordering position per outer loop: a sequence of length `d` costs
//! exactly `d` scheduler steps regardless of RNG, making step counts and
//! drain times analytically checkable.
//!
//! Covered here:
//! * the headline win — on a mixed workload (bulk queue at 10x the
//!   request arrival rate of a small SLO queue) the SLO queue's simulated
//!   p95 queue wait under the weighted scheduler is strictly lower than
//!   under round-robin, and an all-one-queue trace shows zero throughput
//!   loss vs round-robin;
//! * scheduler invariants under randomized traces (seeded PCG, many
//!   seeds): no sequence lost or double-answered, no non-empty queue
//!   starves beyond a bounded number of rounds, weighted step shares of
//!   backlogged queues converge to the configured ratios;
//! * admission backpressure: shed-vs-queue accounting stays conservative.

use std::collections::{BTreeMap, BTreeSet};

use ssmd::coordinator::sched::{CrossQueueScheduler, QueueId, QueuePolicy,
                               SchedConfig};
use ssmd::engine::{BoundStepper, MockModel, Prompt, SeqParams, SlotId,
                   SpecParams, Stepper, Window};
use ssmd::util::ptest::{self, Size};
use ssmd::util::rng::Pcg;
use ssmd::util::simclock::{Clock, SimClock};

#[derive(Clone, Debug)]
struct QueueSpec {
    d: usize,
    vocab: usize,
    bucket: usize,
    model_seed: u64,
    policy: QueuePolicy,
    /// Synthetic virtual cost of one scheduler step of this queue.
    step_cost: f64,
}

impl QueueSpec {
    fn new(d: usize, bucket: usize, step_cost: f64, policy: QueuePolicy)
           -> QueueSpec {
        QueueSpec { d, vocab: 6, bucket, model_seed: 7, policy, step_cost }
    }
}

#[derive(Clone, Copy, Debug)]
struct Arrival {
    t: f64,
    queue: usize,
    n: usize,
    seed: u64,
}

#[derive(Clone, Copy, PartialEq)]
enum Selector {
    RoundRobin,
    Weighted,
}

#[derive(Clone, Debug, PartialEq)]
struct Report {
    /// Per queue: one exact virtual-time queue wait per sequence
    /// (admission -> slot placement), in placement order.
    waits: Vec<Vec<f64>>,
    /// Per queue: scheduler steps executed.
    steps: Vec<u64>,
    /// Per queue: steps executed while *every* queue had work (the
    /// window where weighted shares are defined).
    busy_steps: Vec<u64>,
    /// Per queue: sequences retired.
    finished: Vec<usize>,
    /// Total *sequences* rejected by admission backpressure (a shed
    /// request sheds all of its sequences).
    shed: u64,
    slo_violations: u64,
    /// Largest ready-but-unpicked streak any queue experienced.
    max_starve: u64,
    t_end: f64,
}

/// Replay `trace` against the queues in `specs` under the given selector,
/// in virtual time, until all admitted work drains. Asserts conservation
/// (every admitted sequence finishes exactly once) internally.
fn simulate(specs: &[QueueSpec], trace: &[Arrival], selector: Selector,
            cfg: &SchedConfig) -> Report {
    for w in trace.windows(2) {
        assert!(w[0].t <= w[1].t, "trace must be time-sorted");
    }
    let models: Vec<MockModel> = specs
        .iter()
        .map(|s| {
            let mut m = MockModel::new(s.d, s.vocab, s.model_seed);
            m.buckets = vec![s.bucket];
            m
        })
        .collect();
    let params = SpecParams {
        window: Window::Constant(1),
        ..Default::default()
    };
    let mut steppers: Vec<BoundStepper<'_, MockModel>> = models
        .iter()
        .map(|m| BoundStepper::new(m, SeqParams::Spec(params.clone())))
        .collect();

    let clock = SimClock::new();
    let mut xq = CrossQueueScheduler::new(Box::new(clock.clone()), cfg);
    let qids: Vec<QueueId> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| xq.register(&format!("q{i}"), s.policy.clone()))
        .collect();
    let weighted = selector == Selector::Weighted;

    let nq = specs.len();
    let mut admit_time: Vec<BTreeMap<SlotId, f64>> =
        vec![BTreeMap::new(); nq];
    let mut seen_done: Vec<BTreeSet<SlotId>> = vec![BTreeSet::new(); nq];
    let mut waits: Vec<Vec<f64>> = vec![Vec::new(); nq];
    let mut steps = vec![0u64; nq];
    let mut busy_steps = vec![0u64; nq];
    let mut finished = vec![0usize; nq];
    let mut since_pick = vec![0u64; nq];
    let mut max_starve = 0u64;
    let mut harness_shed = 0u64;
    let mut rr = 0usize;
    let mut next = 0usize;
    let mut ready_buf: Vec<QueueId> = Vec::new();

    loop {
        // Admit everything due at the current virtual time (requests that
        // arrived while the engine was stepping are backdated, exactly as
        // the coordinator backdates channel transit time).
        while next < trace.len() && trace[next].t <= clock.now() + 1e-12 {
            let a = trace[next];
            next += 1;
            let age = (clock.now() - a.t).max(0.0);
            if weighted {
                if !xq.try_enqueue(qids[a.queue], 0, a.n, age) {
                    continue; // shed by admission backpressure
                }
            } else {
                let q = &specs[a.queue].policy;
                let over = admit_time[a.queue].len()
                    - seen_done[a.queue].len()
                    - steppers[a.queue].n_active();
                if q.shed_on_full && over + a.n > q.max_pending {
                    harness_shed += a.n as u64;
                    continue;
                }
            }
            let prompt = Prompt::empty(specs[a.queue].d);
            let mut rng = Pcg::new(a.seed);
            for _ in 0..a.n {
                let sid = steppers[a.queue].admit(&prompt, rng.split());
                admit_time[a.queue].insert(sid, a.t);
            }
        }

        ready_buf.clear();
        for (i, st) in steppers.iter().enumerate() {
            if !st.is_idle() {
                ready_buf.push(qids[i]);
            }
        }
        if ready_buf.is_empty() {
            if next >= trace.len() {
                break;
            }
            clock.set(trace[next].t);
            continue;
        }
        let all_busy = ready_buf.len() == nq;

        let qi = match selector {
            Selector::Weighted => {
                let sid = xq.pick(&ready_buf).expect("ready set non-empty");
                qids.iter().position(|&q| q == sid).unwrap()
            }
            Selector::RoundRobin => {
                // The pre-weighted engine loop: scan from a rotating
                // cursor, step the first non-idle queue.
                let mut chosen = None;
                for off in 0..nq {
                    let i = (rr + off) % nq;
                    if !steppers[i].is_idle() {
                        chosen = Some(i);
                        break;
                    }
                }
                let i = chosen.unwrap();
                rr = i + 1;
                i
            }
        };

        // Starvation accounting, same definition as the selector's: a
        // streak counts rounds a queue was ready but unpicked, and resets
        // whenever the queue is picked or goes idle.
        for (i, st) in steppers.iter().enumerate() {
            if st.is_idle() {
                since_pick[i] = 0;
            } else if i != qi {
                since_pick[i] += 1;
                max_starve = max_starve.max(since_pick[i]);
            }
        }
        since_pick[qi] = 0;

        // One step: placements happen at step start (backfill precedes
        // the forward pass), so waits are measured against t0.
        let t0 = clock.now();
        let done = steppers[qi].step();
        let placed = steppers[qi].take_placements();
        for sid in &placed {
            let at = admit_time[qi]
                .get(sid)
                .copied()
                .expect("placed sequence was admitted");
            waits[qi].push(t0 - at);
        }
        if weighted {
            xq.placed_at(qids[qi], 0, placed.len(), t0, |_| {});
        }
        clock.advance(specs[qi].step_cost);
        if weighted {
            xq.report_step(qids[qi], specs[qi].step_cost);
        }
        steps[qi] += 1;
        if all_busy {
            busy_steps[qi] += 1;
        }
        for (sid, _) in done {
            assert!(seen_done[qi].insert(sid),
                    "sequence {sid:?} answered twice");
            assert!(admit_time[qi].contains_key(&sid),
                    "retired sequence {sid:?} was never admitted");
            finished[qi] += 1;
        }
    }

    for i in 0..nq {
        assert_eq!(finished[i], admit_time[i].len(),
                   "queue {i}: admitted sequences were lost");
        assert_eq!(waits[i].len(), admit_time[i].len(),
                   "queue {i}: placement accounting out of sync");
    }
    Report {
        waits,
        steps,
        busy_steps,
        finished,
        // Sequence-denominated on both paths (shed_of counts sequences;
        // shed_requests counts requests) so conservation arithmetic
        // against per-arrival n stays exact.
        shed: if weighted {
            qids.iter().map(|&q| xq.shed_of(q)).sum()
        } else {
            harness_shed
        },
        slo_violations: xq.slo_violations(),
        max_starve,
        t_end: clock.now(),
    }
}

/// Exact p95 over a non-empty sample (nearest-rank: the ceil(0.95·n)-th
/// smallest value).
fn p95(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((v.len() as f64) * 0.95).ceil() as usize;
    v[rank.max(1).min(v.len()) - 1]
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Headline mixed workload: a bulk queue taking 10 requests/s against a
/// small SLO queue taking 1 request/s (bursts of 4 short sequences).
fn headline_setup() -> (Vec<QueueSpec>, Vec<Arrival>) {
    let specs = vec![
        // Bulk: GPT2-scale stand-in — big batches, expensive steps.
        QueueSpec::new(16, 4, 0.08, QueuePolicy {
            weight: 1.0,
            ..QueuePolicy::default()
        }),
        // SLO: small-vocab latency queue — cheap steps, weighted 4x with
        // a 50ms p95 target and a burst bound wide enough to drain a
        // whole burst between bulk steps.
        QueueSpec::new(12, 1, 0.01, QueuePolicy {
            weight: 4.0,
            slo_p95_s: Some(0.05),
            max_consecutive: 16,
            ..QueuePolicy::default()
        }),
    ];
    let mut trace = Vec::new();
    for k in 0..60 {
        trace.push(Arrival {
            t: 0.1 * k as f64,
            queue: 0,
            n: 1,
            seed: 1000 + k,
        });
    }
    for k in 0..5 {
        trace.push(Arrival {
            t: 0.05 + k as f64,
            queue: 1,
            n: 4,
            seed: 2000 + k,
        });
    }
    trace.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    (specs, trace)
}

#[test]
fn weighted_beats_round_robin_on_slo_queue_p95() {
    let (specs, trace) = headline_setup();
    let cfg = SchedConfig::default();
    let rr = simulate(&specs, &trace, Selector::RoundRobin, &cfg);
    let w = simulate(&specs, &trace, Selector::Weighted, &cfg);
    // Both selectors serve everything.
    assert_eq!(rr.finished, vec![60, 20]);
    assert_eq!(w.finished, vec![60, 20]);
    let (p95_rr, p95_w) = (p95(&rr.waits[1]), p95(&w.waits[1]));
    assert!(
        p95_w < p95_rr,
        "weighted p95 {p95_w:.3}s must beat round-robin {p95_rr:.3}s"
    );
    // The gap is structural, not marginal: bursts drain ~4x faster.
    assert!(
        p95_w < 0.5 * p95_rr,
        "weighted p95 {p95_w:.3}s vs RR {p95_rr:.3}s: gap collapsed"
    );
    assert!(mean(&w.waits[1]) < mean(&rr.waits[1]));
    // The early burst placements exceeded the 50ms SLO before the boost
    // kicked in, so violations were observed and counted.
    assert!(w.slo_violations >= 1);
    // The bulk queue still drains with bounded starvation.
    assert!(w.max_starve <= cfg.starve_after + specs.len() as u64);
}

#[test]
fn all_one_queue_trace_loses_no_throughput() {
    // Adversarial trace: every arrival targets one queue. The weighted
    // selector must degenerate to exactly the round-robin behavior —
    // identical step count, identical drain time, identical waits.
    let specs = vec![QueueSpec::new(12, 2, 0.02, QueuePolicy {
        weight: 3.0,
        slo_p95_s: Some(0.01),
        ..QueuePolicy::default()
    })];
    let mut trace = Vec::new();
    for k in 0..12 {
        trace.push(Arrival {
            t: 0.05 * k as f64,
            queue: 0,
            n: 1 + (k as usize % 3),
            seed: 300 + k,
        });
    }
    let cfg = SchedConfig::default();
    let rr = simulate(&specs, &trace, Selector::RoundRobin, &cfg);
    let w = simulate(&specs, &trace, Selector::Weighted, &cfg);
    assert_eq!(w.steps, rr.steps, "weighted ran extra steps");
    assert_eq!(w.t_end, rr.t_end, "weighted lost throughput");
    assert_eq!(w.waits, rr.waits, "weighted changed single-queue waits");
    assert_eq!(w.finished, rr.finished);
}

#[test]
fn simulation_is_deterministic() {
    let (specs, trace) = headline_setup();
    let cfg = SchedConfig::default();
    let a = simulate(&specs, &trace, Selector::Weighted, &cfg);
    let b = simulate(&specs, &trace, Selector::Weighted, &cfg);
    assert_eq!(a, b, "virtual-time simulation must be bit-reproducible");
}

#[test]
fn shed_policy_is_conservative_and_queue_policy_admits_all() {
    // 20 single-sequence requests land at t=0 on a depth-5 queue.
    let shed_spec = vec![QueueSpec::new(8, 1, 0.01, QueuePolicy {
        max_pending: 5,
        shed_on_full: true,
        ..QueuePolicy::default()
    })];
    let trace: Vec<Arrival> = (0..20)
        .map(|k| Arrival { t: 0.0, queue: 0, n: 1, seed: 50 + k })
        .collect();
    let cfg = SchedConfig::default();
    let r = simulate(&shed_spec, &trace, Selector::Weighted, &cfg);
    assert_eq!(r.shed, 15, "depth-5 bound must shed 15 of 20");
    assert_eq!(r.finished[0], 5);
    // Same trace under queue-on-full: everything is admitted and served.
    let queue_spec = vec![QueueSpec::new(8, 1, 0.01, QueuePolicy {
        max_pending: 5,
        shed_on_full: false,
        ..QueuePolicy::default()
    })];
    let r = simulate(&queue_spec, &trace, Selector::Weighted, &cfg);
    assert_eq!(r.shed, 0);
    assert_eq!(r.finished[0], 20);
}

// ---------------------------------------------------------------------------
// Property tests: randomized admission traces, many seeds
// ---------------------------------------------------------------------------

/// Random trace generator: three adversarial shapes — bursty clusters,
/// heavy-tailed (Pareto-ish) inter-arrivals, and all-one-queue floods.
fn random_case(rng: &mut Pcg, s: Size)
               -> (Vec<QueueSpec>, Vec<Arrival>, u64) {
    let nq = 2 + rng.below(3);
    let specs: Vec<QueueSpec> = (0..nq)
        .map(|_| {
            let policy = QueuePolicy {
                weight: 0.5 + rng.f64() * 3.5,
                slo_p95_s: if rng.below(2) == 0 {
                    Some(0.02 + rng.f64() * 0.2)
                } else {
                    None
                },
                ..QueuePolicy::default()
            };
            QueueSpec {
                d: 8,
                vocab: 4 + rng.below(4),
                bucket: 1 + rng.below(2),
                model_seed: rng.next_u64(),
                policy,
                step_cost: 0.005 + rng.f64() * 0.045,
            }
        })
        .collect();
    let shape = rng.below(3);
    let n_arrivals = 8 + (s.0 * 3).min(16);
    let mut t = 0.0;
    let mut trace = Vec::new();
    for _ in 0..n_arrivals {
        match shape {
            // Bursty: arrivals cluster at shared instants.
            0 => {
                if rng.below(3) == 0 {
                    t += rng.f64() * 0.6;
                }
            }
            // Heavy-tailed inter-arrivals: mostly tiny gaps, rare big
            // ones (t += 0.01 * u^-0.7, capped).
            1 => {
                let u = rng.f64().max(1e-6);
                t += (0.01 * u.powf(-0.7)).min(2.0);
            }
            // Adversarial: everything lands at once.
            _ => {}
        }
        let queue = if shape == 2 { 0 } else { rng.below(nq) };
        trace.push(Arrival {
            t,
            queue,
            n: 1 + rng.below(4),
            seed: rng.next_u64(),
        });
    }
    (specs, trace, rng.next_u64())
}

#[test]
fn property_no_loss_no_double_answer_bounded_starvation() {
    let cfg = SchedConfig { starve_after: 16, ..SchedConfig::default() };
    ptest::check(
        10,
        0x5eed_51,
        random_case,
        |(specs, trace, _)| {
            let r = simulate(specs, trace, Selector::Weighted, &cfg);
            // Conservation is asserted inside simulate(); cross-check the
            // totals against the trace minus sheds here.
            let admitted: usize =
                trace.iter().map(|a| a.n).sum::<usize>()
                    - r.shed as usize;
            let served: usize = r.finished.iter().sum();
            if served != admitted {
                return Err(format!(
                    "served {served} != admitted {admitted}"
                ));
            }
            // Starvation bound: starve_after plus one round per ready
            // queue (simultaneously-starved queues drain one per round).
            let bound = cfg.starve_after + specs.len() as u64;
            if r.max_starve > bound {
                return Err(format!(
                    "starve streak {} exceeds bound {bound}",
                    r.max_starve
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn property_backlogged_step_shares_converge_to_weights() {
    // All queues share identical per-step costs and carry deep backlogs;
    // sequence work is exact (a Constant(1) window decides one position
    // per outer loop, so every sequence costs exactly d steps), so the
    // busy-window step shares must track the weight ratios closely.
    ptest::check(
        8,
        0x5a4e_5,
        |rng: &mut Pcg, _s: Size| {
            let nq = 2 + rng.below(2);
            let weights: Vec<f64> =
                (0..nq).map(|_| 1.0 + rng.f64() * 3.0).collect();
            (nq, weights, rng.next_u64())
        },
        |(nq, weights, seed)| {
            let specs: Vec<QueueSpec> = weights
                .iter()
                .map(|&w| {
                    QueueSpec::new(8, 1, 0.01, QueuePolicy {
                        weight: w,
                        // Shares, not burst shaping, are under test.
                        max_consecutive: u32::MAX,
                        ..QueuePolicy::default()
                    })
                })
                .collect();
            // Deep backlog for every queue, all admitted at t = 0.
            let trace: Vec<Arrival> = (0..*nq)
                .map(|i| Arrival {
                    t: 0.0,
                    queue: i,
                    n: 40,
                    seed: seed ^ i as u64,
                })
                .collect();
            let r = simulate(&specs, &trace, Selector::Weighted,
                             &SchedConfig::default());
            let total: u64 = r.busy_steps.iter().sum();
            let wsum: f64 = weights.iter().sum();
            for i in 0..*nq {
                let got = r.busy_steps[i] as f64 / total as f64;
                let want = weights[i] / wsum;
                if (got - want).abs() > 0.25 * want {
                    return Err(format!(
                        "queue {i}: step share {got:.3} vs weight share \
                         {want:.3} (busy {:?})",
                        r.busy_steps
                    ));
                }
            }
            Ok(())
        },
    );
}
