//! Multi-replica (fleet) virtual-time simulation pins — the sharded
//! serving tentpole's testable core (`ssmd::sim::simulate_fleet`):
//!
//! * **throughput scaling** — on a saturated mixed trace, two replicas
//!   retire tokens at >= 1.5x the aggregate rate of one, at zero
//!   correctness cost (identical token streams);
//! * **migration bitwise-identity** — a mid-sequence checkpoint evicted
//!   on one replica and adopted on another (re-minted `SlotId`, new
//!   selector, new slot table) finishes with exactly the tokens of the
//!   unmigrated and single-replica runs;
//! * **router conservation** — across randomized multi-replica traces
//!   (deadlines, transient and fatal faults included): every admitted
//!   sequence is finished, failed, or deadline-shed, exactly once; no
//!   sequence is answered twice; replays are bit-identical.

use ssmd::coordinator::sched::{QueuePolicy, SchedConfig};
use ssmd::engine::FaultPlan;
use ssmd::sim::{simulate_fleet, Arrival, QueueSpec};
use ssmd::util::ptest::{self, Size};
use ssmd::util::rng::Pcg;

/// Saturated mixed workload: two models with comparable step costs and
/// enough near-simultaneous arrivals that both replicas stay busy for
/// the whole run (the regime where replica scaling is defined).
fn saturated_mixed() -> (Vec<QueueSpec>, Vec<Arrival>) {
    let specs = vec![
        QueueSpec::new(12, 2, 0.03, QueuePolicy::default()),
        QueueSpec::new(8, 1, 0.03, QueuePolicy {
            weight: 2.0,
            ..QueuePolicy::default()
        }),
    ];
    let mut trace = Vec::new();
    for k in 0..24u64 {
        trace.push(Arrival {
            t: 0.01 * k as f64,
            queue: (k % 2) as usize,
            n: 2,
            seed: 5000 + k,
            ..Arrival::default()
        });
    }
    (specs, trace)
}

/// The tentpole's acceptance number: 2 replicas, >= 1.5x aggregate token
/// throughput over 1 replica on a saturated mixed trace — with the
/// *same* token streams (replica count and migration are invisible to
/// results, they only buy time).
#[test]
fn two_replicas_give_1_5x_throughput_at_zero_correctness_cost() {
    let (specs, trace) = saturated_mixed();
    let cfg = SchedConfig::default();
    let one = simulate_fleet(&specs, &trace, 1, &cfg, false);
    let two = simulate_fleet(&specs, &trace, 2, &cfg, true);
    assert_eq!(one.tokens, two.tokens,
               "replica count changed a token stream");
    assert_eq!(one.shed, 0);
    assert_eq!(two.shed, 0);
    let (tp1, tp2) = (one.token_throughput(), two.token_throughput());
    assert!(
        tp2 >= 1.5 * tp1,
        "2-replica throughput {tp2:.1} tok/s must be >= 1.5x \
         single-replica {tp1:.1} tok/s"
    );
}

/// Skewed load: one 8-sequence request lands whole on replica 0 (an
/// arrival is never split), leaving replica 1 idle — the exact shape
/// migration exists for. The run must actually migrate, retire work on
/// the adopting replica, and still produce tokens bitwise identical to
/// both the migration-off and the single-replica run.
#[test]
fn migration_is_exercised_and_bitwise_identical() {
    let specs = vec![QueueSpec::new(8, 4, 0.05, QueuePolicy::default())];
    let trace = vec![Arrival {
        t: 0.0,
        queue: 0,
        n: 8,
        seed: 77,
        ..Arrival::default()
    }];
    let cfg = SchedConfig::default();
    let single = simulate_fleet(&specs, &trace, 1, &cfg, false);
    let stay = simulate_fleet(&specs, &trace, 2, &cfg, false);
    let moved = simulate_fleet(&specs, &trace, 2, &cfg, true);
    assert!(moved.migrations >= 1, "skewed load must trigger migration");
    assert!(moved.finished[1] >= 1,
            "the adopting replica must retire migrated work");
    assert_eq!(stay.migrations, 0);
    assert_eq!(moved.tokens, single.tokens,
               "migration changed a token stream bitwise");
    assert_eq!(moved.tokens, stay.tokens);
    // Migration strictly helps here: the adopter drains work the origin
    // would otherwise serialize.
    assert!(moved.t_end < stay.t_end,
            "migration must shorten the skewed-load drain");
}

#[test]
fn fleet_sim_is_deterministic() {
    let (specs, trace) = saturated_mixed();
    let cfg = SchedConfig::default();
    let a = simulate_fleet(&specs, &trace, 3, &cfg, true);
    let b = simulate_fleet(&specs, &trace, 3, &cfg, true);
    assert_eq!(a, b, "fleet replay diverged");
}

/// Random fleet cases: 1-3 queues, bursty/heavy-tailed/flood arrival
/// shapes, occasional deadlines and fault scripts, 2-3 replicas.
fn random_fleet_case(rng: &mut Pcg, s: Size)
                     -> (Vec<QueueSpec>, Vec<Arrival>, usize) {
    let nq = 1 + rng.below(3);
    let specs: Vec<QueueSpec> = (0..nq)
        .map(|_| {
            let fault = match rng.below(6) {
                0 => Some(FaultPlan::parse("err@3").unwrap()),
                1 => Some(FaultPlan::parse("panic@9").unwrap()),
                _ => None,
            };
            QueueSpec {
                d: 8,
                vocab: 4 + rng.below(4),
                bucket: 1 + rng.below(2),
                model_seed: rng.next_u64(),
                policy: QueuePolicy {
                    weight: 0.5 + rng.f64() * 3.5,
                    ..QueuePolicy::default()
                },
                step_cost: 0.005 + rng.f64() * 0.045,
                fault,
            }
        })
        .collect();
    let shape = rng.below(3);
    let n_arrivals = 6 + (s.0 * 3).min(12);
    let mut t = 0.0;
    let mut trace = Vec::new();
    for _ in 0..n_arrivals {
        match shape {
            0 => {
                if rng.below(3) == 0 {
                    t += rng.f64() * 0.6;
                }
            }
            1 => {
                let u = rng.f64().max(1e-6);
                t += (0.01 * u.powf(-0.7)).min(2.0);
            }
            _ => {}
        }
        trace.push(Arrival {
            t,
            queue: rng.below(nq),
            n: 1 + rng.below(4),
            seed: rng.next_u64(),
            priority: rng.below(3) as i32 - 1,
            deadline: if rng.below(4) == 0 {
                Some(0.05 + rng.f64() * 0.3)
            } else {
                None
            },
        });
    }
    (specs, trace, 2 + rng.below(2))
}

/// The router conservation property: across random multi-replica traces,
/// admitted = finished + failed + deadline-shed (exactly one bucket per
/// sequence — double answers panic inside the harness), replays are
/// bit-identical, and on fault-free deadline-free cases the token
/// streams match the single-replica run bitwise.
#[test]
fn property_fleet_conserves_across_random_traces() {
    let cfg = SchedConfig::default();
    ptest::check(
        10,
        0x5eed_f1,
        random_fleet_case,
        |(specs, trace, ne)| {
            let r = simulate_fleet(specs, trace, *ne, &cfg, true);
            let r2 = simulate_fleet(specs, trace, *ne, &cfg, true);
            if r != r2 {
                return Err("fleet replay diverged".into());
            }
            // Cross-check the harness's internal conservation assert
            // against the raw trace: every sequence of every arrival is
            // admitted, backpressure-shed, or expired in transit.
            let total: usize = trace.iter().map(|a| a.n).sum();
            let done: usize = r.finished.iter().sum();
            let swept_in_flight = r.admitted - done - r.failed;
            let in_transit = r.deadline_sheds as usize - swept_in_flight;
            if r.admitted + r.shed as usize + in_transit != total {
                return Err(format!(
                    "sequences lost: total {total}, admitted {}, shed {}, \
                     in-transit expiries {in_transit}",
                    r.admitted, r.shed
                ));
            }
            let clean = specs.iter().all(|s| s.fault.is_none())
                && trace.iter().all(|a| a.deadline.is_none());
            if clean {
                let one = simulate_fleet(specs, trace, 1, &cfg, false);
                if one.tokens != r.tokens {
                    return Err(
                        "replica count changed token streams".into());
                }
            }
            Ok(())
        },
    );
}
