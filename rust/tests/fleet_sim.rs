//! Multi-replica (fleet) virtual-time simulation pins — the sharded
//! serving tentpole's testable core (`ssmd::sim::simulate_fleet`):
//!
//! * **throughput scaling** — on a saturated mixed trace, two replicas
//!   retire tokens at >= 1.5x the aggregate rate of one, at zero
//!   correctness cost (identical token streams);
//! * **migration bitwise-identity** — a mid-sequence checkpoint evicted
//!   on one replica and adopted on another (re-minted `SlotId`, new
//!   selector, new slot table) finishes with exactly the tokens of the
//!   unmigrated and single-replica runs;
//! * **router conservation** — across randomized multi-replica traces
//!   (deadlines, transient and fatal faults included): every admitted
//!   sequence is finished, failed, or deadline-shed, exactly once; no
//!   sequence is answered twice; replays are bit-identical;
//! * **replica loss** — a scripted mid-run `kill@N` evacuates the
//!   victim's checkpoints onto the migration board, a survivor adopts
//!   them, the victim restarts under supervised backoff, every admitted
//!   sequence still finishes, and the evacuated token streams are
//!   bitwise identical to a kill-free same-seed run — whichever replica
//!   adopts (the property test sweeps `adopter_offset` and randomized
//!   kill scripts).

use ssmd::coordinator::sched::{QueuePolicy, SchedConfig};
use ssmd::coordinator::Liveness;
use ssmd::engine::FaultPlan;
use ssmd::sim::{simulate_fleet, simulate_fleet_opts, Arrival, FleetOptions,
                QueueSpec};
use ssmd::util::ptest::{self, Size};
use ssmd::util::rng::Pcg;
use ssmd::util::simclock::{Clock, SimClock};

/// Saturated mixed workload: two models with comparable step costs and
/// enough near-simultaneous arrivals that both replicas stay busy for
/// the whole run (the regime where replica scaling is defined).
fn saturated_mixed() -> (Vec<QueueSpec>, Vec<Arrival>) {
    let specs = vec![
        QueueSpec::new(12, 2, 0.03, QueuePolicy::default()),
        QueueSpec::new(8, 1, 0.03, QueuePolicy {
            weight: 2.0,
            ..QueuePolicy::default()
        }),
    ];
    let mut trace = Vec::new();
    for k in 0..24u64 {
        trace.push(Arrival {
            t: 0.01 * k as f64,
            queue: (k % 2) as usize,
            n: 2,
            seed: 5000 + k,
            ..Arrival::default()
        });
    }
    (specs, trace)
}

/// The tentpole's acceptance number: 2 replicas, >= 1.5x aggregate token
/// throughput over 1 replica on a saturated mixed trace — with the
/// *same* token streams (replica count and migration are invisible to
/// results, they only buy time).
#[test]
fn two_replicas_give_1_5x_throughput_at_zero_correctness_cost() {
    let (specs, trace) = saturated_mixed();
    let cfg = SchedConfig::default();
    let one = simulate_fleet(&specs, &trace, 1, &cfg, false);
    let two = simulate_fleet(&specs, &trace, 2, &cfg, true);
    assert_eq!(one.tokens, two.tokens,
               "replica count changed a token stream");
    assert_eq!(one.shed, 0);
    assert_eq!(two.shed, 0);
    let (tp1, tp2) = (one.token_throughput(), two.token_throughput());
    assert!(
        tp2 >= 1.5 * tp1,
        "2-replica throughput {tp2:.1} tok/s must be >= 1.5x \
         single-replica {tp1:.1} tok/s"
    );
}

/// Skewed load: one 8-sequence request lands whole on replica 0 (an
/// arrival is never split), leaving replica 1 idle — the exact shape
/// migration exists for. The run must actually migrate, retire work on
/// the adopting replica, and still produce tokens bitwise identical to
/// both the migration-off and the single-replica run.
#[test]
fn migration_is_exercised_and_bitwise_identical() {
    let specs = vec![QueueSpec::new(8, 4, 0.05, QueuePolicy::default())];
    let trace = vec![Arrival {
        t: 0.0,
        queue: 0,
        n: 8,
        seed: 77,
        ..Arrival::default()
    }];
    let cfg = SchedConfig::default();
    let single = simulate_fleet(&specs, &trace, 1, &cfg, false);
    let stay = simulate_fleet(&specs, &trace, 2, &cfg, false);
    let moved = simulate_fleet(&specs, &trace, 2, &cfg, true);
    assert!(moved.migrations >= 1, "skewed load must trigger migration");
    assert!(moved.finished[1] >= 1,
            "the adopting replica must retire migrated work");
    assert_eq!(stay.migrations, 0);
    assert_eq!(moved.tokens, single.tokens,
               "migration changed a token stream bitwise");
    assert_eq!(moved.tokens, stay.tokens);
    // Migration strictly helps here: the adopter drains work the origin
    // would otherwise serialize.
    assert!(moved.t_end < stay.t_end,
            "migration must shorten the skewed-load drain");
}

#[test]
fn fleet_sim_is_deterministic() {
    let (specs, trace) = saturated_mixed();
    let cfg = SchedConfig::default();
    let a = simulate_fleet(&specs, &trace, 3, &cfg, true);
    let b = simulate_fleet(&specs, &trace, 3, &cfg, true);
    assert_eq!(a, b, "fleet replay diverged");
}

/// The replica-loss acceptance scenario (the fleet_kill.jsonl CI trace's
/// in-repo twin): 2 replicas, replica 0 killed on its 3rd step attempt
/// while holding four mid-flight sequences, tight missed-beat threshold,
/// restart budget 2, and a post-restart arrival.
fn kill_case() -> (Vec<QueueSpec>, Vec<Arrival>, FleetOptions) {
    let specs = vec![QueueSpec::new(16, 2, 0.01, QueuePolicy::default())];
    let mut trace: Vec<Arrival> = (0..4)
        .map(|k| Arrival {
            t: 0.0,
            queue: 0,
            n: 2,
            seed: 11 + k,
            ..Arrival::default()
        })
        .collect();
    // Lands after detection + backoff: the respawned replica serves it.
    trace.push(Arrival { t: 1.0, queue: 0, n: 2, seed: 15,
                         ..Arrival::default() });
    let opts = FleetOptions {
        replica_faults: vec![(0, FaultPlan::parse("kill@3").unwrap())],
        heartbeat_timeout_s: 0.5,
        restart_budget: 2,
        ..FleetOptions::default()
    };
    (specs, trace, opts)
}

/// The tentpole's replica-loss acceptance pin: a scripted mid-run kill
/// loses nothing — every admitted sequence finishes (evacuated
/// checkpoints are adopted by the survivor), the victim restarts under
/// supervised backoff and serves again, and every token stream is
/// bitwise identical to the kill-free same-seed fleet.
#[test]
fn scripted_kill_evacuates_restarts_and_loses_nothing() {
    let (specs, trace, opts) = kill_case();
    let cfg = SchedConfig::default();
    let r = simulate_fleet_opts(&specs, &trace, 2, &cfg, opts.clone());
    let r2 = simulate_fleet_opts(&specs, &trace, 2, &cfg, opts.clone());
    assert_eq!(r, r2, "kill replay diverged");
    assert!(r.evacuations >= 1,
            "the kill must evacuate the victim's checkpoints");
    assert!(r.replica_restarts >= 1,
            "the victim must restart under supervision");
    assert_eq!(r.failed, 0);
    assert_eq!(r.brownout_shed, 0, "one replica stayed up throughout");
    let done: usize = r.finished.iter().sum();
    assert_eq!(done, r.admitted, "an admitted sequence was lost");
    assert_eq!(r.admitted, 10, "every arrival admitted");
    assert!(r.finished[0] >= 1,
            "the respawned replica must serve again (t=1 arrival)");
    // Bitwise identity: the kill, the evacuation, and the adopter's
    // identity are invisible to results — same streams as a calm fleet.
    let calm = simulate_fleet_opts(&specs, &trace, 2, &cfg, FleetOptions {
        replica_faults: Vec::new(),
        ..opts
    });
    assert_eq!(r.tokens, calm.tokens,
               "evacuation changed a token stream bitwise");
}

/// Clock skew between replicas is impossible by construction: every
/// replica reads the one shared [`SimClock`] timeline (clones share
/// state), so two beats recorded "now" can never disagree about the
/// missed-beat deadline. The threshold-edge cases (exactly-at-threshold
/// is still Up, strictly-past is Down) are pinned in `router.rs` units.
#[test]
fn shared_simclock_makes_replica_skew_impossible() {
    let a = SimClock::new();
    let b = a.clone();
    a.advance(1.25);
    assert_eq!(a.now(), b.now(), "clone observed a different timeline");
    b.set(3.5);
    assert_eq!(a.now(), 3.5, "set through one handle moves both");
    let mut l = Liveness::new(2, 0.5);
    l.beat(0, a.now());
    l.beat(1, b.now());
    assert_eq!(l.down_at(0), l.down_at(1),
               "same-instant beats must share a missed-beat deadline");
}

/// Random fleet cases: 1-3 queues, bursty/heavy-tailed/flood arrival
/// shapes, occasional deadlines and fault scripts, 2-3 replicas.
fn random_fleet_case(rng: &mut Pcg, s: Size)
                     -> (Vec<QueueSpec>, Vec<Arrival>, usize) {
    let nq = 1 + rng.below(3);
    let specs: Vec<QueueSpec> = (0..nq)
        .map(|_| {
            let fault = match rng.below(6) {
                0 => Some(FaultPlan::parse("err@3").unwrap()),
                1 => Some(FaultPlan::parse("panic@9").unwrap()),
                _ => None,
            };
            QueueSpec {
                d: 8,
                vocab: 4 + rng.below(4),
                bucket: 1 + rng.below(2),
                model_seed: rng.next_u64(),
                policy: QueuePolicy {
                    weight: 0.5 + rng.f64() * 3.5,
                    ..QueuePolicy::default()
                },
                step_cost: 0.005 + rng.f64() * 0.045,
                fault,
            }
        })
        .collect();
    let shape = rng.below(3);
    let n_arrivals = 6 + (s.0 * 3).min(12);
    let mut t = 0.0;
    let mut trace = Vec::new();
    for _ in 0..n_arrivals {
        match shape {
            0 => {
                if rng.below(3) == 0 {
                    t += rng.f64() * 0.6;
                }
            }
            1 => {
                let u = rng.f64().max(1e-6);
                t += (0.01 * u.powf(-0.7)).min(2.0);
            }
            _ => {}
        }
        trace.push(Arrival {
            t,
            queue: rng.below(nq),
            n: 1 + rng.below(4),
            seed: rng.next_u64(),
            priority: rng.below(3) as i32 - 1,
            deadline: if rng.below(4) == 0 {
                Some(0.05 + rng.f64() * 0.3)
            } else {
                None
            },
        });
    }
    (specs, trace, 2 + rng.below(2))
}

/// The router conservation property: across random multi-replica traces,
/// admitted = finished + failed + deadline-shed (exactly one bucket per
/// sequence — double answers panic inside the harness), replays are
/// bit-identical, and on fault-free deadline-free cases the token
/// streams match the single-replica run bitwise.
#[test]
fn property_fleet_conserves_across_random_traces() {
    let cfg = SchedConfig::default();
    ptest::check(
        10,
        0x5eed_f1,
        random_fleet_case,
        |(specs, trace, ne)| {
            let r = simulate_fleet(specs, trace, *ne, &cfg, true);
            let r2 = simulate_fleet(specs, trace, *ne, &cfg, true);
            if r != r2 {
                return Err("fleet replay diverged".into());
            }
            // Cross-check the harness's internal conservation assert
            // against the raw trace: every sequence of every arrival is
            // admitted, backpressure-shed, or expired in transit.
            let total: usize = trace.iter().map(|a| a.n).sum();
            let done: usize = r.finished.iter().sum();
            let swept_in_flight = r.admitted - done - r.failed;
            let in_transit = r.deadline_sheds as usize - swept_in_flight;
            if r.admitted + r.shed as usize + in_transit != total {
                return Err(format!(
                    "sequences lost: total {total}, admitted {}, shed {}, \
                     in-transit expiries {in_transit}",
                    r.admitted, r.shed
                ));
            }
            let clean = specs.iter().all(|s| s.fault.is_none())
                && trace.iter().all(|a| a.deadline.is_none());
            if clean {
                let one = simulate_fleet(specs, trace, 1, &cfg, false);
                if one.tokens != r.tokens {
                    return Err(
                        "replica count changed token streams".into());
                }
            }
            Ok(())
        },
    );
}

/// Random replica-kill cases: fault-free deadline-free queues (so token
/// streams are comparable against a kill-free run), 2-3 replicas, 1-2
/// `kill@N` scripts on random replicas, randomized missed-beat
/// threshold.
fn random_kill_case(rng: &mut Pcg, s: Size)
                    -> (Vec<QueueSpec>, Vec<Arrival>, usize,
                        Vec<(usize, FaultPlan)>, f64) {
    let nq = 1 + rng.below(2);
    let specs: Vec<QueueSpec> = (0..nq)
        .map(|_| {
            QueueSpec {
                d: 8,
                vocab: 4 + rng.below(4),
                bucket: 1 + rng.below(2),
                model_seed: rng.next_u64(),
                policy: QueuePolicy::default(),
                step_cost: 0.005 + rng.f64() * 0.045,
                fault: None,
            }
        })
        .collect();
    let n_arrivals = 6 + (s.0 * 3).min(10);
    let mut t = 0.0;
    let mut trace = Vec::new();
    for _ in 0..n_arrivals {
        if rng.below(3) == 0 {
            t += rng.f64() * 0.4;
        }
        trace.push(Arrival {
            t,
            queue: rng.below(nq),
            n: 1 + rng.below(3),
            seed: rng.next_u64(),
            ..Arrival::default()
        });
    }
    let ne = 2 + rng.below(2);
    let kills: Vec<(usize, FaultPlan)> = (0..1 + rng.below(2))
        .map(|_| {
            let spec = format!("kill@{}", 1 + rng.below(12));
            (rng.below(ne), FaultPlan::parse(&spec).unwrap())
        })
        .collect();
    let heartbeat = 0.1 + rng.f64() * 0.5;
    (specs, trace, ne, kills, heartbeat)
}

/// The evacuation-identity property: across randomized replica-kill
/// scripts, every adopter choice (`adopter_offset` swept) produces the
/// *same* report — and every token stream the chaos fleet retires is
/// bitwise identical to the kill-free same-seed fleet's stream for that
/// (arrival, sequence). Conservation holds throughout: nothing admitted
/// is lost (kills under a restart budget are loss-free), and arrivals
/// are only ever rejected by total brown-out.
#[test]
fn property_kills_conserve_and_evacuation_is_bitwise_invisible() {
    let cfg = SchedConfig::default();
    ptest::check(
        8,
        0x5eed_f2,
        random_kill_case,
        |(specs, trace, ne, kills, heartbeat)| {
            let opts_at = |off: usize| FleetOptions {
                migrate: false,
                replica_faults: kills.clone(),
                heartbeat_timeout_s: *heartbeat,
                restart_budget: 2,
                adopter_offset: off,
            };
            let calm = simulate_fleet_opts(specs, trace, *ne, &cfg,
                                           FleetOptions {
                                               replica_faults: Vec::new(),
                                               ..opts_at(0)
                                           });
            let base = simulate_fleet_opts(specs, trace, *ne, &cfg,
                                           opts_at(0));
            for off in 0..3usize {
                let r = simulate_fleet_opts(specs, trace, *ne, &cfg,
                                            opts_at(off));
                let r2 = simulate_fleet_opts(specs, trace, *ne, &cfg,
                                             opts_at(off));
                if r != r2 {
                    return Err(format!("offset {off}: replay diverged"));
                }
                // Loss-free: a kill under restart budget loses nothing.
                let done: usize = r.finished.iter().sum();
                if r.failed != 0 || done != r.admitted {
                    return Err(format!(
                        "offset {off}: admitted {} but done {done}, \
                         failed {}",
                        r.admitted, r.failed
                    ));
                }
                // Every sequence of every arrival is admitted or
                // brown-out-rejected (no backpressure in these cases).
                let total: usize = trace.iter().map(|a| a.n).sum();
                if r.admitted + r.brownout_shed as usize != total {
                    return Err(format!(
                        "offset {off}: sequences lost: total {total}, \
                         admitted {}, brownout {}",
                        r.admitted, r.brownout_shed
                    ));
                }
                // The adopter's identity is invisible to results. (If a
                // total brown-out fired, the *answer set* may shift with
                // kill timing — which shifts with adopter load — so the
                // full-map comparison only applies brown-out-free; the
                // per-key calm comparison below covers the rest.)
                if r.brownout_shed == 0
                    && base.brownout_shed == 0
                    && r.tokens != base.tokens
                {
                    return Err(format!(
                        "offset {off}: adopter choice changed a token \
                         stream"
                    ));
                }
                // Evacuated or not, every retired stream matches the
                // kill-free same-seed fleet bitwise (brown-out may make
                // the chaos run's answer set a subset of the calm one).
                for (k, stream) in &r.tokens {
                    if calm.tokens.get(k) != Some(stream) {
                        return Err(format!(
                            "offset {off}: stream for arrival {} seq {} \
                             differs from the kill-free run",
                            k.0, k.1
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
