//! Allocation-regression guard for the scheduler hot path.
//!
//! The continuous-batching scheduler owns a `StepArena` of per-step
//! buffers (token/sigma staging, both logits buffers, the draft-LSE
//! table, the residual scratch row) and the sampling primitives are
//! allocation-free logits-domain kernels, so once the first step has
//! warmed every capacity a steady-state `SpecScheduler::step` must touch
//! the heap **zero** times. This test pins that invariant with a counting
//! `#[global_allocator]`: any future change that sneaks an allocation
//! into the hot loop (a probability-vector materialization, a per-row
//! clone, a payload build in the mock) fails here, not in a profile.
//!
//! This file must stay a single #[test]: the counter is process-global,
//! so a concurrently running second test would pollute the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use ssmd::engine::{MdmParams, MockModel, Prompt, SeqParams, SpecParams,
                   SpecScheduler, Window};
use ssmd::util::rng::Pcg;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn warm_scheduler_steps_allocate_nothing() {
    // ---- speculative path -------------------------------------------------
    let d = 128;
    let mut model = MockModel::new(d, 16, 0xa110c);
    model.buckets = vec![1];
    let mut sched = SpecScheduler::for_model(&model);
    let params = SpecParams {
        // Small cosine windows: many outer loops, none of which can
        // finish the sequence inside the measured region.
        window: Window::Cosine { dtau: 0.02 },
        ..Default::default()
    };
    sched.admit(&Prompt::empty(d), SeqParams::Spec(params), Pcg::new(1));
    // Warm the arena: first steps size every buffer (and the first
    // rejection sizes the residual scratch row's length).
    for _ in 0..3 {
        sched.step(&model);
    }
    assert!(!sched.is_idle(), "warmup must not finish the sequence");

    let before = allocs();
    for _ in 0..4 {
        sched.step(&model);
    }
    let spec_allocs = allocs() - before;
    assert!(
        !sched.is_idle(),
        "measured steps must not retire the sequence (retirement is \
         allowed to allocate)"
    );
    assert_eq!(
        spec_allocs, 0,
        "warm speculative steps must not allocate (got {spec_allocs} \
         allocations across 4 steps)"
    );

    // ---- MDM path ---------------------------------------------------------
    let mut sched = SpecScheduler::for_model(&model);
    let params = MdmParams { steps: 4096, temperature: 1.0 };
    sched.admit(&Prompt::empty(d), SeqParams::Mdm(params), Pcg::new(2));
    for _ in 0..3 {
        sched.step(&model);
    }
    assert!(!sched.is_idle(), "warmup must not finish the sequence");

    let before = allocs();
    for _ in 0..4 {
        sched.step(&model);
    }
    let mdm_allocs = allocs() - before;
    assert!(!sched.is_idle());
    assert_eq!(
        mdm_allocs, 0,
        "warm MDM steps must not allocate (got {mdm_allocs} allocations \
         across 4 steps)"
    );
}
