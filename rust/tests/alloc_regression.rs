//! Allocation-regression guard for the scheduler hot path.
//!
//! The continuous-batching scheduler owns a `StepArena` of per-step
//! buffers (token/sigma staging, both logits buffers, the draft-LSE
//! table, the residual scratch row) and the sampling primitives are
//! allocation-free logits-domain kernels, so once the first step has
//! warmed every capacity a steady-state `SpecScheduler::step` must touch
//! the heap **zero** times. This test pins that invariant with a counting
//! `#[global_allocator]`: any future change that sneaks an allocation
//! into the hot loop (a probability-vector materialization, a per-row
//! clone, a payload build in the mock) fails here, not in a profile.
//!
//! This file must stay a single #[test]: the counter is process-global,
//! so a concurrently running second test would pollute the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use std::sync::Arc;

use ssmd::coordinator::sched::{CrossQueueScheduler, QueueId, QueuePolicy,
                               SchedConfig};
use ssmd::engine::{MdmParams, MockModel, Prompt, SeqParams, SpecParams,
                   SpecScheduler, StepPool, Window};
use ssmd::util::rng::Pcg;
use ssmd::util::simclock::MonotonicClock;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

// SAFETY: pure pass-through to `System` plus a relaxed counter bump —
// every GlobalAlloc contract obligation is delegated unchanged.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to `System` under the caller's layout contract.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: delegates to `System` under the caller's layout contract.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: delegates to `System` under the caller's layout contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout,
                      new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: delegates to `System` under the caller's layout contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// One engine-loop cycle on the weighted selector: pick a queue, step it,
/// report placements at the pre-step instant, charge the step cost.
#[allow(clippy::too_many_arguments)]
fn xq_cycle(xq: &mut CrossQueueScheduler, ready: &[QueueId], qa: QueueId,
            sched_a: &mut SpecScheduler, model_a: &MockModel,
            sched_b: &mut SpecScheduler, model_b: &MockModel) {
    let pick = xq.pick(ready).expect("both queues live");
    let (sched, model) = if pick == qa {
        (sched_a, model_a)
    } else {
        (sched_b, model_b)
    };
    let t0 = xq.now();
    sched.step(model);
    let placed = sched.take_placements();
    xq.placed_at(pick, 0, placed.len(), t0, |_| {});
    xq.report_step(pick, 1e-3);
}

#[test]
fn warm_scheduler_steps_allocate_nothing() {
    // ---- speculative path -------------------------------------------------
    let d = 128;
    let mut model = MockModel::new(d, 16, 0xa110c);
    model.buckets = vec![1];
    let mut sched = SpecScheduler::for_model(&model);
    let params = SpecParams {
        // Small cosine windows: many outer loops, none of which can
        // finish the sequence inside the measured region.
        window: Window::Cosine { dtau: 0.02 },
        ..Default::default()
    };
    sched.admit(&Prompt::empty(d), SeqParams::Spec(params), Pcg::new(1));
    // Warm the arena: first steps size every buffer (and the first
    // rejection sizes the residual scratch row's length).
    for _ in 0..3 {
        sched.step(&model);
    }
    assert!(!sched.is_idle(), "warmup must not finish the sequence");

    let before = allocs();
    for _ in 0..4 {
        sched.step(&model);
    }
    let spec_allocs = allocs() - before;
    assert!(
        !sched.is_idle(),
        "measured steps must not retire the sequence (retirement is \
         allowed to allocate)"
    );
    assert_eq!(
        spec_allocs, 0,
        "warm speculative steps must not allocate (got {spec_allocs} \
         allocations across 4 steps)"
    );

    // ---- MDM path ---------------------------------------------------------
    let mut sched = SpecScheduler::for_model(&model);
    let params = MdmParams { steps: 4096, temperature: 1.0 };
    sched.admit(&Prompt::empty(d), SeqParams::Mdm(params), Pcg::new(2));
    for _ in 0..3 {
        sched.step(&model);
    }
    assert!(!sched.is_idle(), "warmup must not finish the sequence");

    let before = allocs();
    for _ in 0..4 {
        sched.step(&model);
    }
    let mdm_allocs = allocs() - before;
    assert!(!sched.is_idle());
    assert_eq!(
        mdm_allocs, 0,
        "warm MDM steps must not allocate (got {mdm_allocs} allocations \
         across 4 steps)"
    );

    // ---- pooled planar path (step_threads = 2, 2 residents) --------------
    // The planar phases dispatch through the step pool's mutex/condvar
    // hand-off (workers pre-spawned at pool construction), which must
    // not touch the heap: no per-step channel, closure box, or Vec
    // churn. Two residents so every phase really crosses the pool (one
    // resident takes the inline single-chunk shortcut).
    let pool = Arc::new(StepPool::new(2));
    let mut model2 = MockModel::new(d, 16, 0xa110c);
    model2.buckets = vec![2];
    let mut sched = SpecScheduler::for_model(&model2);
    sched.set_pool(pool.clone());
    let params = SpecParams {
        window: Window::Cosine { dtau: 0.02 },
        ..Default::default()
    };
    sched.admit(&Prompt::empty(d), SeqParams::Spec(params.clone()),
                Pcg::new(7));
    sched.admit(&Prompt::empty(d), SeqParams::Spec(params), Pcg::new(8));
    for _ in 0..3 {
        sched.step(&model2);
    }
    assert_eq!(sched.n_active(), 2, "both sequences must stay resident");

    let before = allocs();
    for _ in 0..4 {
        sched.step(&model2);
    }
    let pooled_allocs = allocs() - before;
    assert_eq!(sched.n_active(), 2,
               "measured pooled steps must not retire a sequence");
    assert_eq!(
        pooled_allocs, 0,
        "warm pooled planar steps must not allocate (got {pooled_allocs} \
         allocations across 4 steps with step_threads=2)"
    );
    drop(sched);
    drop(pool);

    // ---- weighted cross-queue selector path -------------------------------
    // Multiple live queues through the full engine-loop cycle
    // (pick -> step -> placed_at -> report_step): credit/EWMA bookkeeping
    // lives in fixed per-queue state, so warm cycles must stay
    // allocation-free too. Queue a carries an (absurd) 1ns SLO so the
    // boost/violation arithmetic is exercised, not skipped.
    let mut model_a = MockModel::new(d, 16, 0xa110c);
    model_a.buckets = vec![1];
    let mut model_b = MockModel::new(d, 16, 0xb10c);
    model_b.buckets = vec![1];
    let mut sched_a = SpecScheduler::for_model(&model_a);
    let mut sched_b = SpecScheduler::for_model(&model_b);
    let params = SpecParams {
        window: Window::Cosine { dtau: 0.02 },
        ..Default::default()
    };
    sched_a.admit(&Prompt::empty(d), SeqParams::Spec(params.clone()),
                  Pcg::new(3));
    sched_b.admit(&Prompt::empty(d), SeqParams::Spec(params), Pcg::new(4));
    let mut xq = CrossQueueScheduler::new(
        Box::new(MonotonicClock::new()), &SchedConfig::default());
    let qa = xq.register("a", QueuePolicy {
        weight: 3.0,
        slo_p95_s: Some(1e-9),
        ..QueuePolicy::default()
    });
    let qb = xq.register("b", QueuePolicy::default());
    assert!(xq.try_enqueue(qa, 0, 0, 1, 0.0));
    assert!(xq.try_enqueue(qb, 0, 0, 1, 0.0));
    let ready = [qa, qb];
    // Pre-warm both arenas directly (3 steps each — the SLO boost would
    // otherwise keep the selector on queue a and leave queue b's arena
    // cold until the measured region) and drain both arrival stamps; the
    // nonzero wait queue a observes here blows its 1ns SLO, arming the
    // boost arithmetic for the measured cycles.
    for _ in 0..3 {
        sched_a.step(&model_a);
        sched_b.step(&model_b);
    }
    let placed_a = sched_a.take_placements();
    xq.placed(qa, 0, placed_a.len(), |_| {});
    let placed_b = sched_b.take_placements();
    xq.placed(qb, 0, placed_b.len(), |_| {});
    assert!(xq.wait_ewma(qa) > 1e-9, "SLO boost must be armed");
    // Warm the selector cycle itself.
    for _ in 0..2 {
        xq_cycle(&mut xq, &ready, qa, &mut sched_a, &model_a,
                 &mut sched_b, &model_b);
    }
    assert!(!sched_a.is_idle() && !sched_b.is_idle(),
            "warmup must not finish either sequence");

    let before = allocs();
    for _ in 0..4 {
        xq_cycle(&mut xq, &ready, qa, &mut sched_a, &model_a,
                 &mut sched_b, &model_b);
    }
    let xq_allocs = allocs() - before;
    assert!(!sched_a.is_idle() && !sched_b.is_idle(),
            "measured cycles must not retire a sequence");
    assert_eq!(
        xq_allocs, 0,
        "warm weighted-selector cycles must not allocate (got \
         {xq_allocs} allocations across 4 cycles)"
    );
}
