//! Thread-count / SIMD invariance of the planar scheduler.
//!
//! The planar step loop's determinism contract: for one seeded workload,
//! **token streams and every metrics counter are bitwise identical** no
//! matter how many step-pool threads execute the phases. Each row's
//! noise stream is counter-based per (row, pcg-draw) and residents are
//! mutually independent, so the executor count can only change wall
//! time, never results. This file pins that at three levels — raw
//! scheduler (speculative + MDM) and the full coordinator with
//! `SchedConfig::step_threads` — at `step_threads ∈ {1, 2, 8}`.
//!
//! SIMD invariance rides on the same pin: CI runs this test with and
//! without `--features simd`, and the block kernels the sampler calls
//! are asserted bit-identical to the portable reference inside
//! `engine::kernels::tests::dispatched_blocks_match_portable_bitwise`,
//! so the streams asserted here are the same streams in both builds.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use ssmd::coordinator::{
    BatcherConfig, Coordinator, EngineModel, GenRequest, ModelMap,
    SamplerChoice, SchedConfig,
};
use ssmd::engine::{
    MdmParams, MockModel, Prompt, SeqParams, SpecParams, SpecScheduler,
    StepPool, Window,
};
use ssmd::util::rng::Pcg;

const D: usize = 24;
const V: usize = 12;

/// A mixed workload: empty prompts, partially-revealed prompts, and
/// enough sequences to exercise backfill through a small bucket ladder.
fn prompts() -> Vec<Prompt> {
    (0..10)
        .map(|i| {
            let mut p = Prompt::empty(D);
            if i % 3 == 1 {
                for pos in 0..D / 2 {
                    p.0[pos] = Some(((pos + i) % V) as i32);
                }
            }
            p
        })
        .collect()
}

fn model() -> MockModel {
    let mut m = MockModel::new(D, V, 0x51d);
    m.buckets = vec![1, 2, 4];
    m
}

/// Everything the workload observes: per-sequence token streams (in
/// admission order) plus every scheduler counter and stat.
#[derive(Debug, PartialEq, Eq)]
struct Fingerprint {
    tokens: Vec<Vec<i32>>,
    steps: u64,
    row_steps: u64,
    padded_row_steps: u64,
    backfills: u64,
    accepted: usize,
    rejected: usize,
    verify_passes: usize,
    outer_loops: usize,
}

fn run_spec(threads: usize) -> Fingerprint {
    let m = model();
    let mut sched = SpecScheduler::for_model(&m);
    sched.set_pool(Arc::new(StepPool::new(threads)));
    let params = SpecParams {
        window: Window::Cosine { dtau: 0.08 },
        n_verify: 2,
        temperature: 0.7,
        ..Default::default()
    };
    let mut rng = Pcg::new(0xbeef);
    let ids: Vec<_> = prompts()
        .iter()
        .map(|p| sched.admit(p, SeqParams::Spec(params.clone()),
                             rng.split()))
        .collect();
    let mut done = BTreeMap::new();
    while !sched.is_idle() {
        for (id, s) in sched.step(&m) {
            done.insert(id, s);
        }
    }
    let stats = sched.take_stats();
    Fingerprint {
        tokens: ids
            .iter()
            .map(|id| done.remove(id).expect("retired").tokens)
            .collect(),
        steps: sched.steps(),
        row_steps: sched.row_steps(),
        padded_row_steps: sched.padded_row_steps(),
        backfills: sched.backfills(),
        accepted: stats.accepted,
        rejected: stats.rejected,
        verify_passes: stats.verify_passes,
        outer_loops: stats.outer_loops,
    }
}

fn run_mdm(threads: usize) -> Fingerprint {
    let m = model();
    let mut sched = SpecScheduler::for_model(&m);
    sched.set_pool(Arc::new(StepPool::new(threads)));
    let params = MdmParams { steps: 12, temperature: 0.7 };
    let mut rng = Pcg::new(0xfeed);
    let ids: Vec<_> = prompts()
        .iter()
        .map(|p| sched.admit(p, SeqParams::Mdm(params.clone()),
                             rng.split()))
        .collect();
    let mut done = BTreeMap::new();
    while !sched.is_idle() {
        for (id, s) in sched.step(&m) {
            done.insert(id, s);
        }
    }
    let stats = sched.take_stats();
    Fingerprint {
        tokens: ids
            .iter()
            .map(|id| done.remove(id).expect("retired").tokens)
            .collect(),
        steps: sched.steps(),
        row_steps: sched.row_steps(),
        padded_row_steps: sched.padded_row_steps(),
        backfills: sched.backfills(),
        accepted: stats.accepted,
        rejected: stats.rejected,
        verify_passes: stats.verify_passes,
        outer_loops: stats.outer_loops,
    }
}

#[test]
fn spec_workload_is_thread_count_invariant() {
    let base = run_spec(1);
    assert!(base.rejected > 0,
            "workload must exercise the residual path, not just accepts");
    assert!(base.backfills > 0, "workload must exercise backfill");
    for t in [2usize, 8] {
        assert_eq!(run_spec(t), base, "step_threads={t} diverged");
    }
}

#[test]
fn mdm_workload_is_thread_count_invariant() {
    let base = run_mdm(1);
    for t in [2usize, 8] {
        assert_eq!(run_mdm(t), base, "step_threads={t} diverged");
    }
}

/// Checkpoint migration between schedulers — the sharded engines' evict
/// → adopt path: sequences evicted mid-run from scheduler A and adopted
/// by scheduler B (a *different* `SlotId` namespace, as replica id
/// bases differ) must finish with token streams bitwise identical to
/// the uninterrupted single-scheduler run. The per-sequence RNG stream
/// travels inside the checkpoint; the slot id is only a routing label.
#[test]
fn migrated_sequences_are_bitwise_identical() {
    use ssmd::engine::SlotId;
    let m = model();
    let params = SpecParams {
        window: Window::Cosine { dtau: 0.08 },
        n_verify: 2,
        temperature: 0.7,
        ..Default::default()
    };
    // Baseline: the same admissions run to completion in one place.
    let baseline: Vec<Vec<i32>> = {
        let mut sched = SpecScheduler::for_model(&m);
        let mut rng = Pcg::new(0x517e);
        let ids: Vec<_> = prompts()
            .iter()
            .map(|p| sched.admit(p, SeqParams::Spec(params.clone()),
                                 rng.split()))
            .collect();
        let mut done = BTreeMap::new();
        while !sched.is_idle() {
            for (id, s) in sched.step(&m) {
                done.insert(id, s);
            }
        }
        ids.iter().map(|id| done.remove(id).expect("retired").tokens)
            .collect()
    };
    // Migrated run: admit on A, then after a few steps evict two
    // residents mid-sequence and adopt them on B.
    let mut a = SpecScheduler::for_model(&m);
    let mut b = SpecScheduler::for_model(&m);
    b.set_id_base(1u64 << 40);
    let mut rng = Pcg::new(0x517e);
    let ids: Vec<_> = prompts()
        .iter()
        .map(|p| a.admit(p, SeqParams::Spec(params.clone()), rng.split()))
        .collect();
    let mut done_a = BTreeMap::new();
    let mut done_b = BTreeMap::new();
    let mut moved: BTreeMap<SlotId, SlotId> = BTreeMap::new();
    let mut rounds = 0u32;
    while !a.is_idle() || !b.is_idle() {
        if !a.is_idle() {
            for (id, s) in a.step(&m) {
                done_a.insert(id, s);
            }
        }
        if !b.is_idle() {
            for (id, s) in b.step(&m) {
                done_b.insert(id, s);
            }
        }
        rounds += 1;
        if rounds == 3 {
            for _ in 0..2 {
                if let Some(ck) = a.evict_lowest() {
                    let old = ck.id();
                    let new = b.adopt(ck);
                    assert_ne!(old, new,
                               "adoption must re-mint into B's namespace");
                    moved.insert(old, new);
                }
            }
        }
    }
    assert_eq!(moved.len(), 2, "workload must actually migrate");
    let migrated: Vec<Vec<i32>> = ids
        .iter()
        .map(|id| match moved.get(id) {
            Some(nid) => done_b.remove(nid).expect("migrant retired").tokens,
            None => done_a.remove(id).expect("retired").tokens,
        })
        .collect();
    assert_eq!(migrated, baseline,
               "migration changed a token stream bitwise");
}

fn coordinator_with_threads(step_threads: usize) -> Coordinator {
    Coordinator::start(
        || {
            let mut m: ModelMap = BTreeMap::new();
            let mut mm = MockModel::new(D, V, 0x51d);
            mm.buckets = vec![1, 2, 4];
            m.insert("mock".into(), Box::new(mm) as Box<dyn EngineModel>);
            Ok(m)
        },
        BatcherConfig {
            max_wait: Duration::from_millis(1),
            sched: SchedConfig { step_threads, ..Default::default() },
            ..Default::default()
        },
    )
    .unwrap()
}

/// End-to-end wiring of `--step-threads`: a deterministic request must
/// return identical samples whether the engine's shared pool has 1 or 4
/// workers, for both samplers.
#[test]
fn coordinator_results_are_step_thread_invariant() {
    let single = coordinator_with_threads(1);
    let pooled = coordinator_with_threads(4);
    for sampler in [
        SamplerChoice::Speculative(SpecParams {
            n_verify: 2,
            ..Default::default()
        }),
        SamplerChoice::Mdm(MdmParams { steps: 8, temperature: 1.0 }),
    ] {
        let req = GenRequest {
            model: "mock".into(),
            n_samples: 6,
            sampler,
            seed: 4242,
            deterministic: true,
            ..Default::default()
        };
        let a = single.generate(req.clone()).unwrap();
        let b = pooled.generate(req).unwrap();
        assert_eq!(a.samples.len(), b.samples.len());
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.accepted, y.accepted);
            assert_eq!(x.rejected, y.rejected);
            assert!((x.nfe - y.nfe).abs() < 1e-12);
        }
    }
    single.shutdown();
    pooled.shutdown();
}
