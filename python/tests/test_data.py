"""Synthetic corpora + oracle metrics. Includes golden values that the rust
oracle implementations must reproduce (mirrored in rust unit tests)."""

import numpy as np

from train import data as D
from train import hmm as H


def test_lexicon_deterministic_and_clean():
    a = D.make_lexicon(64, seed=5)
    b = D.make_lexicon(64, seed=5)
    assert a == b
    assert len(set(a)) == 64
    for w in a:
        assert 2 <= len(w) <= 10
        assert w.isalpha() and w.islower()


def test_chain_probabilities_normalized():
    c = D.BigramChain(32, seed=9)
    np.testing.assert_allclose(c.trans.sum(axis=1), 1.0, atol=1e-12)
    np.testing.assert_allclose(c.init.sum(), 1.0, atol=1e-9)
    # Stationarity: pi @ T == pi.
    np.testing.assert_allclose(c.init @ c.trans, c.init, atol=1e-9)


def test_nll_matches_hand_computation():
    c = D.BigramChain(8, seed=3)
    toks = np.array([0, 1, 2])
    expect = -(np.log(c.init[0]) + np.log(c.trans[0, 1])
               + np.log(c.trans[1, 2])) / 3
    assert abs(c.nll_tokens(toks) - expect) < 1e-12


def test_real_samples_score_near_entropy_rate():
    c = D.BigramChain(32, seed=9)
    rng = np.random.default_rng(0)
    toks = c.sample_words(4000, rng)
    nll = c.nll_tokens(toks)
    # Entropy rate of the chain.
    h = -(c.init[:, None] * c.trans * np.log(c.trans)).sum()
    assert abs(nll - h) < 0.15, (nll, h)


def test_char_stream_is_words_and_spaces():
    c = D.BigramChain(16, seed=2)
    rng = np.random.default_rng(1)
    ids = D.char_stream(c, 500, rng)
    text = "".join(D.id_char(int(i)) for i in ids)
    vocab = set(c.lexicon)
    words = [w for w in text.split(" ") if w]
    # Interior words (not clipped at the window edge) must be in-lexicon.
    assert all(w in vocab for w in words[1:-1])


def test_spelling_accuracy_metric():
    c = D.BigramChain(16, seed=2)
    rng = np.random.default_rng(1)
    ids = D.char_stream(c, 256, rng)
    acc = D.spelling_accuracy(ids[None], c.lexicon)
    assert acc > 0.8  # only boundary words can be clipped
    garbage = np.ones((1, 256), dtype=np.int32) * 17  # "qqq..."
    assert D.spelling_accuracy(garbage, c.lexicon) == 0.0


def test_unigram_entropy():
    assert D.unigram_entropy(np.array([[3, 3, 3, 3]])) == 0.0
    e = D.unigram_entropy(np.array([[0, 1, 2, 3]]))
    assert abs(e - np.log(4)) < 1e-12


def test_corpora_batches():
    char_chain, word_chain = D.default_chains()
    cc = D.CharCorpus(char_chain, 32, n_chars=5000, seed=1)
    rng = np.random.default_rng(0)
    b = cc.batch(rng, 4)
    assert b.shape == (4, 32)
    assert b.max() < 27
    wc = D.WordCorpus(word_chain, 16, n_tokens=2000, seed=1)
    b = wc.batch(rng, 4)
    assert b.shape == (4, 16)
    assert b.max() < word_chain.n_words


def test_hmm_forward_matches_enumeration():
    hmm = H.ProteinHMM(n_states=3, seed=1)
    seq = np.array([0, 5, 19, 7], dtype=np.int32)
    # Enumerate hidden paths.
    K, T = 3, len(seq)
    total = 0.0
    for path in np.ndindex(*([K] * T)):
        p = hmm.init[path[0]] * hmm.emis[path[0], seq[0]]
        for t in range(1, T):
            p *= hmm.trans[path[t - 1], path[t]] * hmm.emis[path[t], seq[t]]
        total += p
    assert abs(hmm.loglik(seq) - np.log(total)) < 1e-10


def test_plddt_proxy_separates_real_from_garbage():
    hmm = H.default_hmm(48)
    rng = np.random.default_rng(7)
    real = [hmm.plddt_proxy(hmm.sample(48, rng)) for _ in range(32)]
    junk = [hmm.plddt_proxy(rng.integers(0, 20, 48)) for _ in range(32)]
    assert np.mean(real) > np.mean(junk) + 10
    assert 60 < np.mean(real) <= 100


def test_spec_serialization_roundtrip(tmp_path):
    import json
    c = D.BigramChain(8, seed=3)
    spec = c.to_spec()
    path = tmp_path / "spec.json"
    D.save_spec(str(path), spec)
    loaded = json.loads(path.read_text())
    assert loaded["lexicon"] == c.lexicon
    np.testing.assert_allclose(loaded["trans"], c.trans)

    hmm = H.ProteinHMM(4, seed=2)
    hmm.save_spec(str(tmp_path / "h.json"))
    loaded = json.loads((tmp_path / "h.json").read_text())
    assert len(loaded["emis"]) == 4
