"""L1 correctness: Pallas kernel vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shapes/dtypes; every case asserts allclose between
`masked_flash_attention` and `reference_attention` for both bias modes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (causal_bias, masked_flash_attention,
                                       vmem_footprint_bytes, zero_bias)
from compile.kernels.ref import reference_attention


def rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    d=st.sampled_from([4, 8, 16, 64]),
    dk=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_kernel_matches_reference(b, h, d, dk, causal, dtype):
    keys = jax.random.split(jax.random.PRNGKey(b * 100 + h * 10 + d), 3)
    q, k, v = (rand(kk, (b, h, d, dk), dtype) for kk in keys)
    bias = causal_bias(d) if causal else zero_bias(d)
    out = masked_flash_attention(q, k, v, bias)
    ref = reference_attention(q, k, v, bias)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=atol)


def test_block_sizes_do_not_change_result():
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (rand(kk, (2, 2, 64, 16), jnp.float32) for kk in keys)
    bias = causal_bias(64)
    base = masked_flash_attention(q, k, v, bias, block_q=64, block_k=64)
    for bq, bk in [(8, 8), (16, 32), (32, 16), (64, 8)]:
        out = masked_flash_attention(q, k, v, bias, block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, base, atol=1e-5, rtol=1e-5)


def test_causal_bias_blocks_future():
    # With causal bias, output at position 0 must depend only on kv[0].
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (rand(kk, (1, 1, 8, 4), jnp.float32) for kk in keys)
    out1 = masked_flash_attention(q, k, v, causal_bias(8))
    v2 = v.at[:, :, 1:, :].set(0.0)
    k2 = k.at[:, :, 1:, :].set(1.0)
    out2 = masked_flash_attention(q, k2, v2, causal_bias(8))
    np.testing.assert_allclose(out1[:, :, 0], out2[:, :, 0], atol=1e-6)


def test_gradients_flow_through_kernel():
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (rand(kk, (1, 2, 16, 8), jnp.float32) for kk in keys)
    bias = zero_bias(16)

    def loss_kernel(q, k, v):
        return jnp.sum(masked_flash_attention(q, k, v, bias) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(reference_attention(q, k, v, bias) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_fully_masked_row_is_finite_and_matches_ref():
    # A fully -1e30-biased row degenerates to uniform attention (the
    # sentinel is finite); the contract is "no NaN and kernel == ref".
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (rand(kk, (1, 1, 4, 4), jnp.float32) for kk in keys)
    bias = jnp.full((4, 4), -1e30, dtype=jnp.float32)
    out = masked_flash_attention(q, k, v, bias)
    ref = reference_attention(q, k, v, bias)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_vmem_footprint_model():
    # Perf-model sanity: footprint grows with D and stays under 16 MiB for
    # the shapes we ship.
    small = vmem_footprint_bytes(64, 16)
    big = vmem_footprint_bytes(1024, 64)
    assert small < big < 16 * 1024 * 1024
