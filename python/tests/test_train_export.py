"""Training-step and AOT-export smoke tests on tiny configs (fast)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import ModelConfig
from train import losses as L
from train import optim as O


def tiny_cfg():
    return ModelConfig(vocab_size=6, seq_len=8, hidden=16, heads=2, ffn=32,
                       n_noncausal=1, n_causal=1)


def test_one_training_step_reduces_nothing_catastrophic():
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = O.adam_init(params)
    x = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 6)
    sigma, n_rev = L.sample_masking(jax.random.PRNGKey(2), cfg, 4)
    (loss, _), grads = jax.value_and_grad(
        lambda p: L.eq9_loss(p, cfg, x, sigma, n_rev), has_aux=True)(params)
    grads, gn = O.clip_by_global_norm(grads, 1.0)
    assert float(gn) > 0
    new_params, opt = O.adam_update(params, grads, opt, lr=1e-3)
    (loss2, _), _ = jax.value_and_grad(
        lambda p: L.eq9_loss(p, cfg, x, sigma, n_rev), has_aux=True)(
            new_params)
    assert np.isfinite(float(loss2))


def test_warmup_cosine_schedule():
    lr0 = O.warmup_cosine(jnp.asarray(1), peak_lr=1.0, warmup=10, total=100)
    lr_peak = O.warmup_cosine(jnp.asarray(10), peak_lr=1.0, warmup=10,
                              total=100)
    lr_end = O.warmup_cosine(jnp.asarray(100), peak_lr=1.0, warmup=10,
                             total=100)
    assert float(lr0) < float(lr_peak)
    assert abs(float(lr_peak) - 1.0) < 1e-6
    assert float(lr_end) < 0.01


def test_hlo_export_roundtrip(tmp_path):
    """Export a tiny model to HLO text and re-execute it with jax's own
    XLA client — validates the text pipeline without the rust side (which
    tests/pjrt_parity.rs covers)."""
    from compile.aot import to_hlo_text
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    draft_fn = M.make_draft_fn(params, cfg)
    spec = jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32)
    text = to_hlo_text(draft_fn, (spec,))
    assert "HloModule" in text
    out = tmp_path / "m.hlo.txt"
    out.write_text(text)
    assert out.stat().st_size > 1000


def test_aot_export_cli(tmp_path):
    """Full aot.py CLI on a freshly trained 2-step checkpoint."""
    runs = tmp_path / "runs"
    (runs / "tinymodel").mkdir(parents=True)
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    M.save_params(str(runs / "tinymodel" / "ckpt.npz"), params, cfg)
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "artifacts"
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--runs", str(runs),
         "--out", str(out), "--models", "tinymodel"],
        cwd=repo_py, env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr
    assert (out / "manifest.json").exists()
    import json
    manifest = json.loads((out / "manifest.json").read_text())
    entry = manifest["models"]["tinymodel"]
    assert "golden" in entry
    for fname in entry["draft"].values():
        assert (out / fname).exists()
