"""Eq. 9 objective tests: masking schedule, weighting, component split."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.config import ModelConfig
from train import losses as L


def tiny_cfg():
    return ModelConfig(vocab_size=6, seq_len=10, hidden=16, heads=2,
                       ffn=32, n_noncausal=1, n_causal=1)


def test_sample_masking_shapes_and_bounds():
    cfg = tiny_cfg()
    sigma, n_rev = L.sample_masking(jax.random.PRNGKey(0), cfg, 64)
    assert sigma.shape == (64, 10)
    assert n_rev.shape == (64,)
    # p(i = D) = 0: at least one mask always.
    assert int(jnp.max(n_rev)) <= 9
    assert int(jnp.min(n_rev)) >= 0
    # Each row is a permutation.
    s = np.sort(np.asarray(sigma), axis=1)
    np.testing.assert_array_equal(s, np.tile(np.arange(10), (64, 1)))


def test_apply_masking_masks_exactly_the_suffix():
    cfg = tiny_cfg()
    x = jnp.arange(10, dtype=jnp.int32)[None] % 6
    sigma = jnp.asarray([[3, 1, 4, 0, 2, 9, 7, 5, 8, 6]], dtype=jnp.int32)
    n_rev = jnp.asarray([4], dtype=jnp.int32)
    masked, mask = L.apply_masking(cfg, x, sigma, n_rev)
    revealed = {3, 1, 4, 0}
    for pos in range(10):
        if pos in revealed:
            assert int(masked[0, pos]) == int(x[0, pos])
            assert not bool(mask[0, pos])
        else:
            assert int(masked[0, pos]) == cfg.mask_id
            assert bool(mask[0, pos])


def test_losses_are_mean_over_masked():
    # With an untrained (random) model the loss should be near ln V for
    # both components, independent of how many positions are masked.
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    x = jax.random.randint(jax.random.PRNGKey(2), (8, 10), 0, 6)
    sigma, _ = L.sample_masking(jax.random.PRNGKey(3), cfg, 8)
    for n in [0, 5, 9]:
        n_rev = jnp.full((8,), n, dtype=jnp.int32)
        lnc, lc = L.hybrid_losses(params, cfg, x, sigma, n_rev)
        assert 0.5 * np.log(6) < float(lnc) < 2.5 * np.log(6)
        assert 0.5 * np.log(6) < float(lc) < 2.5 * np.log(6)


def test_causal_first_position_equals_draft_term():
    # With i=0 the causal loss includes the draft's term for sigma(0); if
    # everything is masked and D=1... emulate by comparing the two losses
    # on a 1-step reveal: they must share that term.
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    x = jax.random.randint(jax.random.PRNGKey(5), (4, 10), 0, 6)
    sigma, _ = L.sample_masking(jax.random.PRNGKey(6), cfg, 4)
    n_rev = jnp.zeros((4,), dtype=jnp.int32)
    lnc, lc = L.hybrid_losses(params, cfg, x, sigma, n_rev)
    assert np.isfinite(float(lnc)) and np.isfinite(float(lc))


def test_mdm_loss_equals_noncausal_component():
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(7), cfg)
    x = jax.random.randint(jax.random.PRNGKey(8), (4, 10), 0, 6)
    sigma, n_rev = L.sample_masking(jax.random.PRNGKey(9), cfg, 4)
    lnc, lc = L.hybrid_losses(params, cfg, x, sigma, n_rev)
    mdm, _ = L.mdm_loss(params, cfg, x, sigma, n_rev)
    np.testing.assert_allclose(float(mdm), float(lnc), rtol=1e-5)


def test_gradients_flow_to_both_halves():
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(10), cfg)
    x = jax.random.randint(jax.random.PRNGKey(11), (4, 10), 0, 6)
    sigma, n_rev = L.sample_masking(jax.random.PRNGKey(12), cfg, 4)
    grads = jax.grad(
        lambda p: L.eq9_loss(p, cfg, x, sigma, n_rev)[0])(params)
    g_nc = float(jnp.sum(jnp.abs(grads["nc_blocks"][0]["wq"])))
    g_c = float(jnp.sum(jnp.abs(grads["c_blocks"][0]["wq"])))
    assert g_nc > 0.0
    assert g_c > 0.0


def test_causal_only_loss_freezes_backbone_gradient_path():
    # causal_only_loss still backprops into theta (paper fine-tunes with a
    # frozen backbone via the optimizer mask, not by detaching), so here we
    # just check the trainable mask zeroes the update.
    from train import optim as O
    cfg = tiny_cfg()
    params = M.init_params(jax.random.PRNGKey(13), cfg)
    mask = O.trainable_mask_for_head(params)
    assert mask["embed"] == 0.0
    assert mask["nc_blocks"][0]["wq"] == 0.0
    assert mask["c_blocks"][0]["wq"] == 1.0
    assert mask["c_in_w"] == 1.0
    grads = jax.tree_util.tree_map(jnp.ones_like, params)
    opt = O.adam_init(params)
    new, _ = O.adam_update(params, grads, opt, lr=0.1, trainable=mask)
    np.testing.assert_allclose(new["embed"], params["embed"])
    assert not np.allclose(new["c_in_w"], params["c_in_w"])
