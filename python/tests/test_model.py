"""L2 architecture tests: shapes, causal dependency structure, the output
residual, parameter (de)serialization."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(vocab_size=11, seq_len=12, hidden=32, heads=2,
                      ffn=64, n_noncausal=2, n_causal=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_shapes(setup):
    cfg, params = setup
    B, D = 3, cfg.seq_len
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, D), 0, cfg.n_embed)
    h, logits = M.draft_forward(params, cfg, toks)
    assert h.shape == (B, D, cfg.hidden)
    assert logits.shape == (B, D, cfg.vocab_size)
    sigma = jnp.tile(jnp.arange(D, dtype=jnp.int32)[None], (B, 1))
    full = toks % cfg.vocab_size
    tl = M.verify_forward(params, cfg, h, full, sigma)
    assert tl.shape == (B, D, cfg.vocab_size)


def test_draft_is_permutation_equivariant_in_batch(setup):
    cfg, params = setup
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, cfg.seq_len), 0,
                              cfg.n_embed)
    _, l_all = M.draft_forward(params, cfg, toks)
    _, l0 = M.draft_forward(params, cfg, toks[:1])
    np.testing.assert_allclose(l_all[0], l0[0], atol=1e-5)


def test_causal_track_ignores_future_tokens(setup):
    """Track j's output must not change when tokens later in sigma change."""
    cfg, params = setup
    B, D = 1, cfg.seq_len
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (B, D), 0, cfg.vocab_size)
    masked = jnp.full((B, D), cfg.mask_id, dtype=jnp.int32)
    h = M.noncausal_hiddens(params, cfg, masked)
    sigma = jax.random.permutation(jax.random.PRNGKey(4), D)[None].astype(
        jnp.int32)
    tl1 = M.verify_forward(params, cfg, h, toks, sigma)
    # Mutate the token at the LAST ordering position.
    last_pos = int(sigma[0, -1])
    toks2 = toks.at[0, last_pos].set((toks[0, last_pos] + 1)
                                     % cfg.vocab_size)
    tl2 = M.verify_forward(params, cfg, h, toks2, sigma)
    # Tracks 0..D-2 predict sigma[1..D-1]; their causal prefixes exclude
    # the last ordering position, so they must be identical.
    np.testing.assert_allclose(tl1[0, :-2], tl2[0, :-2], atol=1e-5)


def test_causal_track_uses_past_tokens(setup):
    cfg, params = setup
    B, D = 1, cfg.seq_len
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, D), 0,
                              cfg.vocab_size)
    masked = jnp.full((B, D), cfg.mask_id, dtype=jnp.int32)
    h = M.noncausal_hiddens(params, cfg, masked)
    sigma = jnp.arange(D, dtype=jnp.int32)[None]
    tl1 = M.verify_forward(params, cfg, h, toks, sigma)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    tl2 = M.verify_forward(params, cfg, h, toks2, sigma)
    # Track 1 (predicting position 2) attends to position 0: must differ.
    assert not np.allclose(tl1[0, 1], tl2[0, 1], atol=1e-7)


def test_output_residual_initializes_target_near_draft():
    """With zero-init causal output influence removed... the residual means
    a freshly initialized causal block produces logits close to the draft
    head applied to the non-causal hiddens of the predicted position."""
    cfg = ModelConfig(vocab_size=7, seq_len=8, hidden=16, heads=2, ffn=32,
                      n_noncausal=1, n_causal=1, residual_out=True)
    params = M.init_params(jax.random.PRNGKey(7), cfg)
    # Zero the causal blocks' output projections -> pure residual path.
    for blk in params["c_blocks"]:
        blk["wo"] = jnp.zeros_like(blk["wo"])
        blk["w2"] = jnp.zeros_like(blk["w2"])
        blk["b2"] = jnp.zeros_like(blk["b2"])
    params["c_lnf_g"] = jnp.zeros_like(params["c_lnf_g"])  # kill LN path
    params["c_lnf_b"] = jnp.zeros_like(params["c_lnf_b"])
    toks = jax.random.randint(jax.random.PRNGKey(8), (1, 8), 0, 7)
    masked = jnp.full((1, 8), cfg.mask_id, dtype=jnp.int32)
    h, draft_logits = M.draft_forward(params, cfg, masked)
    sigma = jnp.arange(8, dtype=jnp.int32)[None]
    tl = M.verify_forward(params, cfg, h, toks, sigma)
    # Track j predicts position j+1: equals draft logits at position j+1.
    np.testing.assert_allclose(tl[0, :-1], np.asarray(draft_logits)[0, 1:],
                               atol=1e-5)


def test_no_residual_ablation_changes_output():
    base = ModelConfig(vocab_size=7, seq_len=8, hidden=16, heads=2, ffn=32,
                       n_noncausal=1, n_causal=1, residual_out=True)
    params = M.init_params(jax.random.PRNGKey(9), base)
    ablat = ModelConfig(**{**base.to_dict(), "residual_out": False})
    toks = jax.random.randint(jax.random.PRNGKey(10), (1, 8), 0, 7)
    masked = jnp.full((1, 8), base.mask_id, dtype=jnp.int32)
    h = M.noncausal_hiddens(params, base, masked)
    sigma = jnp.arange(8, dtype=jnp.int32)[None]
    a = M.verify_forward(params, base, h, toks, sigma)
    b = M.verify_forward(params, ablat, h, toks, sigma)
    assert not np.allclose(a, b)


def test_param_save_load_roundtrip(tmp_path, setup):
    cfg, params = setup
    path = str(tmp_path / "p.npz")
    M.save_params(path, params, cfg)
    loaded, cfg2 = M.load_params(path)
    assert cfg2 == cfg
    flat_a = M.flatten_params(params)
    flat_b = M.flatten_params(loaded)
    assert set(flat_a) == set(flat_b)
    for k in flat_a:
        np.testing.assert_allclose(flat_a[k], flat_b[k])


def test_param_count_positive(setup):
    cfg, params = setup
    n = M.param_count(params)
    assert n > 10_000
