"""Synthetic corpora replacing the paper's data gates (DESIGN.md Sec. 3).

text8 (Sec. 5.1) -> **char-level synthetic text8**: a deterministic lexicon of
pronounceable words from a consonant-vowel syllable grammar, composed into a
character stream by a word-level bigram Markov chain. 27-token vocabulary
(space=0, a..z=1..26) exactly like text8. The paper's *spelling accuracy*
metric (fraction of generated words present in the corpus vocabulary)
transfers verbatim.

OpenWebText (Sec. 5.2) -> **word-level synthetic corpus**: the same bigram
chain sampled at the word-token level. Because we own the generator, the
"GPT2 NLL" judge is replaced by the *exact* oracle NLL (nats/token) of a
sample under the true chain — a strictly cleaner generative-perplexity judge.

Both generator specs are serialized to JSON so the rust oracle
(rust/src/oracle/) scores samples with bit-identical probabilities.
"""

from __future__ import annotations

import json
from typing import Dict, List, Tuple

import numpy as np

CHAR_VOCAB = 27  # space + a..z
SPACE = 0


def char_id(c: str) -> int:
    return 0 if c == " " else (ord(c) - ord("a") + 1)


def id_char(i: int) -> str:
    return " " if i == 0 else chr(ord("a") + i - 1)


def make_lexicon(n_words: int, seed: int = 1234) -> List[str]:
    """Deterministic pronounceable lexicon from a CV syllable grammar."""
    rng = np.random.default_rng(seed)
    consonants = list("bcdfghjklmnpqrstvwz")
    vowels = list("aeiou")
    words: List[str] = []
    seen = set()
    while len(words) < n_words:
        n_syll = int(rng.integers(1, 4))
        w = ""
        for _ in range(n_syll):
            w += rng.choice(consonants) + rng.choice(vowels)
            if rng.random() < 0.3:
                w += rng.choice(consonants)
        if 2 <= len(w) <= 10 and w not in seen:
            seen.add(w)
            words.append(w)
    return words


class BigramChain:
    """Word-level bigram Markov chain with full support (smoothed).

    trans[i, j] = p(next=j | cur=i); init = exact stationary distribution
    (power iteration), so oracle NLL of a mid-stream window is well defined.
    """

    def __init__(self, n_words: int, seed: int = 1234, n_succ: int = 10,
                 smooth: float = 0.05):
        rng = np.random.default_rng(seed + 1)
        self.lexicon = make_lexicon(n_words, seed)
        W = n_words
        trans = np.zeros((W, W), dtype=np.float64)
        for i in range(W):
            succ = rng.choice(W, size=min(n_succ, W), replace=False)
            w = rng.dirichlet(np.ones(len(succ)) * 0.5)
            trans[i, succ] = w
        self.trans = (1.0 - smooth) * trans + smooth / W
        # Stationary distribution by power iteration.
        pi = np.full(W, 1.0 / W)
        for _ in range(200):
            pi = pi @ self.trans
            pi /= pi.sum()
        self.init = pi
        self._rng = np.random.default_rng(seed + 2)

    @property
    def n_words(self) -> int:
        return len(self.lexicon)

    def sample_words(self, n: int, rng=None) -> np.ndarray:
        rng = rng or self._rng
        out = np.empty(n, dtype=np.int64)
        out[0] = rng.choice(self.n_words, p=self.init)
        for t in range(1, n):
            out[t] = rng.choice(self.n_words, p=self.trans[out[t - 1]])
        return out

    def nll_tokens(self, tokens: np.ndarray) -> float:
        """Exact oracle NLL (nats/token) of a word-token window."""
        lp = np.log(self.init[tokens[0]])
        for a, b in zip(tokens[:-1], tokens[1:]):
            lp += np.log(self.trans[a, b])
        return float(-lp / len(tokens))

    def to_spec(self) -> Dict:
        return {
            "type": "word_bigram",
            "lexicon": self.lexicon,
            "init": self.init.tolist(),
            "trans": self.trans.tolist(),
        }


def char_stream(chain: BigramChain, n_chars: int, rng) -> np.ndarray:
    """Character stream 'w1 w2 w3 ...' encoded to ids, length >= n_chars."""
    ids: List[int] = []
    prev = None
    while len(ids) < n_chars:
        if prev is None:
            prev = rng.choice(chain.n_words, p=chain.init)
        else:
            prev = rng.choice(chain.n_words, p=chain.trans[prev])
        for c in chain.lexicon[prev]:
            ids.append(char_id(c))
        ids.append(SPACE)
    return np.asarray(ids[:n_chars], dtype=np.int32)


class CharCorpus:
    """Synthetic text8: char windows of length D from the bigram stream."""

    def __init__(self, chain: BigramChain, seq_len: int, n_chars: int = 400_000,
                 seed: int = 99):
        rng = np.random.default_rng(seed)
        self.stream = char_stream(chain, n_chars, rng)
        self.seq_len = seq_len
        self.vocab = CHAR_VOCAB

    def batch(self, rng, batch_size: int) -> np.ndarray:
        starts = rng.integers(0, len(self.stream) - self.seq_len,
                              size=batch_size)
        return np.stack([self.stream[s:s + self.seq_len] for s in starts])


class WordCorpus:
    """Synthetic OpenWebText: word-token windows of length D."""

    def __init__(self, chain: BigramChain, seq_len: int,
                 n_tokens: int = 200_000, seed: int = 99):
        rng = np.random.default_rng(seed)
        self.stream = chain.sample_words(n_tokens, rng).astype(np.int32)
        self.seq_len = seq_len
        self.vocab = chain.n_words

    def batch(self, rng, batch_size: int) -> np.ndarray:
        starts = rng.integers(0, len(self.stream) - self.seq_len,
                              size=batch_size)
        return np.stack([self.stream[s:s + self.seq_len] for s in starts])


def spelling_accuracy(samples: np.ndarray, lexicon: List[str]) -> float:
    """Paper Sec. 5.1 metric: fraction of whitespace-delimited lowercase
    words in the samples that appear in the training lexicon."""
    vocab = set(lexicon)
    total, good = 0, 0
    for row in samples:
        text = "".join(id_char(int(i)) for i in row)
        for w in text.split(" "):
            if not w:
                continue
            total += 1
            good += int(w in vocab)
    return good / max(total, 1)


def unigram_entropy(tokens: np.ndarray) -> float:
    """Per-sample unigram token entropy (nats), averaged — Sec. 5.2."""
    ents = []
    for row in np.atleast_2d(tokens):
        _, counts = np.unique(row, return_counts=True)
        p = counts / counts.sum()
        ents.append(float(-(p * np.log(p)).sum()))
    return float(np.mean(ents))


def save_spec(path: str, spec: Dict) -> None:
    with open(path, "w") as f:
        json.dump(spec, f)


def default_chains() -> Tuple[BigramChain, BigramChain]:
    """(char-task chain, word-task chain) with the repo's fixed seeds."""
    return BigramChain(192, seed=1234), BigramChain(256, seed=4321)
