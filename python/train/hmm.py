"""Synthetic protein substrate (DESIGN.md Sec. 3, paper Sec. 5.3).

UniRef50 -> a 20-symbol HMM with motif-block structure (helix/sheet-like
emission profiles chained with high advance probability, separated by loop
states). ESMFold pLDDT -> an exact-likelihood proxy: the HMM forward
algorithm gives the true per-residue log-likelihood of a sequence under the
generating distribution; a fixed logistic calibration (fit on real samples)
maps it to a [0, 100] "pLDDT" scale where real data scores ~85 — preserving
the property Fig. 4 relies on: sequences that better follow the natural
distribution score higher.

The HMM spec (+ calibration) is serialized to JSON for the rust scorer
(rust/src/oracle/hmm.rs), which must reproduce the same numbers.
"""

from __future__ import annotations

import json
from typing import Dict

import numpy as np

N_AA = 20


class ProteinHMM:
    def __init__(self, n_states: int = 12, seed: int = 777):
        rng = np.random.default_rng(seed)
        K = n_states
        # Emissions: peaked Dirichlet -> motif-specific residue preferences.
        emis = rng.dirichlet(np.full(N_AA, 0.25), size=K)
        # Transitions: banded "advance through motif" structure with jumps.
        trans = np.zeros((K, K))
        for i in range(K):
            trans[i, (i + 1) % K] = 0.75          # advance
            trans[i, i] = 0.15                    # dwell
            jumps = rng.choice(K, size=3, replace=False)
            trans[i, jumps] += rng.dirichlet(np.ones(3)) * 0.10
        trans /= trans.sum(axis=1, keepdims=True)
        init = rng.dirichlet(np.ones(K))
        self.K, self.emis, self.trans, self.init = K, emis, trans, init
        self._rng = np.random.default_rng(seed + 1)
        self.calib_mu = 0.0
        self.calib_sigma = 1.0
        self.calib_scale = 1.5
        self.calib_offset = 1.7

    def sample(self, length: int, rng=None) -> np.ndarray:
        rng = rng or self._rng
        out = np.empty(length, dtype=np.int32)
        z = rng.choice(self.K, p=self.init)
        for t in range(length):
            out[t] = rng.choice(N_AA, p=self.emis[z])
            z = rng.choice(self.K, p=self.trans[z])
        return out

    def batch(self, rng, batch_size: int, length: int) -> np.ndarray:
        return np.stack([self.sample(length, rng) for _ in range(batch_size)])

    def loglik(self, seq: np.ndarray) -> float:
        """Exact log p(seq) via the (scaled) forward algorithm."""
        a = self.init * self.emis[:, seq[0]]
        ll = np.log(a.sum())
        a /= a.sum()
        for t in range(1, len(seq)):
            a = (a @ self.trans) * self.emis[:, seq[t]]
            s = a.sum()
            ll += np.log(s)
            a /= s
        return float(ll)

    def per_residue_ll(self, seq: np.ndarray) -> float:
        return self.loglik(seq) / len(seq)

    def calibrate(self, length: int, n: int = 512, seed: int = 5) -> None:
        """Fit the pLDDT-proxy logistic so real data scores high (~85)."""
        rng = np.random.default_rng(seed)
        lls = [self.per_residue_ll(self.sample(length, rng))
               for _ in range(n)]
        self.calib_mu = float(np.mean(lls))
        self.calib_sigma = float(np.std(lls) + 1e-9)

    def plddt_proxy(self, seq: np.ndarray) -> float:
        z = (self.per_residue_ll(seq) - self.calib_mu) / self.calib_sigma
        x = self.calib_scale * z + self.calib_offset
        return float(100.0 / (1.0 + np.exp(-x)))

    def to_spec(self) -> Dict:
        return {
            "type": "protein_hmm",
            "init": self.init.tolist(),
            "trans": self.trans.tolist(),
            "emis": self.emis.tolist(),
            "calib_mu": self.calib_mu,
            "calib_sigma": self.calib_sigma,
            "calib_scale": self.calib_scale,
            "calib_offset": self.calib_offset,
        }

    def save_spec(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_spec(), f)


def default_hmm(seq_len: int) -> ProteinHMM:
    hmm = ProteinHMM(n_states=12, seed=777)
    hmm.calibrate(seq_len)
    return hmm
