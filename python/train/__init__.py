# Build-time training package: synthetic data, Eq. 9 loss, Adam, drivers.
