"""Training driver (build-time only).

Tasks (each writes runs/<name>/{losses.csv, ckpt.npz, spec json}):

  text8            hybrid model on char-level synthetic text8   (Fig. 2/3, Tab. 2)
  owt              hybrid model on word-level corpus            (Tab. 1, Fig. 6)
  owt_nores        Tab. 1 ablation: residual_out = False
  owt_2c           Tab. 1 ablation: 2 causal blocks (paper: 10nc-2c)
  protein_backbone MDM-only backbone on the HMM corpus          (Fig. 4, Fig. 7)
  protein_head     frozen backbone + 1 causal block fine-tune   (Fig. 4, Fig. 7)

Usage: python -m train.train --task text8 --steps 1200 --batch 32 --out runs
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.config import ModelConfig, owt_config, protein_config, text8_config
from train import data as D
from train import hmm as H
from train import losses as L
from train import optim as O


def make_step(cfg: ModelConfig, loss_fn, lr_kw, trainable=None):
    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt, x, sigma, n_rev):
        (loss, (lnc, lc)), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, x, sigma, n_rev), has_aux=True)(params)
        grads, gn = O.clip_by_global_norm(grads, 1.0)
        lr = O.warmup_cosine(opt["t"] + 1, **lr_kw)
        params, opt = O.adam_update(params, grads, opt, lr=lr,
                                    weight_decay=0.03, trainable=trainable)
        return params, opt, lnc, lc
    return step


def train_loop(name, cfg, corpus_batch, loss_fn, steps, batch, out_dir,
               init_from=None, trainable=None, seed=0, log_every=25):
    os.makedirs(os.path.join(out_dir, name), exist_ok=True)
    key = jax.random.PRNGKey(seed)
    if init_from is not None:
        params, loaded_cfg = M.load_params(init_from)
        # Extend a backbone checkpoint to a hybrid config if needed: the
        # causal half is freshly initialized, the rest is copied.
        if loaded_cfg.n_causal != cfg.n_causal or loaded_cfg != cfg:
            fresh = M.init_params(key, cfg)
            for k in params:
                fresh[k] = params[k]
            params = fresh
    else:
        params = M.init_params(key, cfg)
    opt = O.adam_init(params)
    lr_kw = dict(peak_lr=3e-4, warmup=min(200, steps // 10 + 1), total=steps)
    step = make_step(cfg, loss_fn, lr_kw, trainable)
    rng = np.random.default_rng(seed + 1)
    log_path = os.path.join(out_dir, name, "losses.csv")
    t0 = time.time()
    # Continued runs append to the existing loss log with a step offset so
    # Fig. 2/6/7 show the full curve.
    step_offset = 0
    mode = "w"
    if init_from is not None and os.path.exists(log_path):
        with open(log_path) as f:
            lines = [l for l in f.read().strip().splitlines()[1:] if l]
        if lines:
            step_offset = int(lines[-1].split(",")[0])
            mode = "a"
    with open(log_path, mode) as log:
        if mode == "w":
            log.write("step,loss_noncausal,loss_causal,elapsed_s\n")
        ln_acc, lc_acc, n_acc = 0.0, 0.0, 0
        for it in range(1, steps + 1):
            x = jnp.asarray(corpus_batch(rng, batch))
            key, sub = jax.random.split(key)
            sigma, n_rev = L.sample_masking(sub, cfg, batch)
            params, opt, lnc, lc = step(params, opt, x, sigma, n_rev)
            ln_acc += float(lnc); lc_acc += float(lc); n_acc += 1
            if it % log_every == 0 or it == steps:
                log.write(f"{it + step_offset},{ln_acc/n_acc:.6f},"
                          f"{lc_acc/n_acc:.6f},{time.time()-t0:.1f}\n")
                log.flush()
                print(f"[{name}] step {it}/{steps} nc={ln_acc/n_acc:.4f} "
                      f"c={lc_acc/n_acc:.4f} ({time.time()-t0:.0f}s)",
                      flush=True)
                ln_acc, lc_acc, n_acc = 0.0, 0.0, 0
    ckpt = os.path.join(out_dir, name, "ckpt.npz")
    M.save_params(ckpt, params, cfg)
    print(f"[{name}] saved {ckpt} ({M.param_count(params)} params, "
          f"{time.time()-t0:.0f}s)", flush=True)
    return ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", required=True)
    ap.add_argument("--steps", type=int, default=1200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--out", default="runs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--init-from", default=None)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    char_chain, word_chain = D.default_chains()

    if args.task == "text8":
        cfg = text8_config()
        corpus = D.CharCorpus(char_chain, cfg.seq_len)
        D.save_spec(os.path.join(args.out, "text8_spec.json"),
                    char_chain.to_spec())
        train_loop("text8", cfg, corpus.batch, L.eq9_loss, args.steps,
                   args.batch, args.out, seed=args.seed)
    elif args.task in ("owt", "owt_nores", "owt_2c"):
        kw = {}
        if args.task == "owt_nores":
            kw["residual_out"] = False
        if args.task == "owt_2c":
            kw.update(n_noncausal=2, n_causal=2)
        cfg = owt_config(**kw)
        corpus = D.WordCorpus(word_chain, cfg.seq_len)
        D.save_spec(os.path.join(args.out, "owt_spec.json"),
                    word_chain.to_spec())
        train_loop(args.task, cfg, corpus.batch, L.eq9_loss, args.steps,
                   args.batch, args.out, seed=args.seed)
    elif args.task == "protein_backbone":
        cfg = protein_config(n_causal=0)
        # n_causal=0 is invalid for the hybrid fwd; train MDM loss on a
        # hybrid-shaped model instead so the checkpoint layout is uniform.
        cfg = protein_config()
        hmm = H.default_hmm(cfg.seq_len)
        hmm.save_spec(os.path.join(args.out, "protein_spec.json"))
        corpus_batch = lambda rng, b: hmm.batch(rng, b, cfg.seq_len)
        train_loop("protein_backbone", cfg, corpus_batch, L.mdm_loss,
                   args.steps, args.batch, args.out, seed=args.seed)
    elif args.task == "protein_head":
        cfg = protein_config()
        hmm = H.default_hmm(cfg.seq_len)
        corpus_batch = lambda rng, b: hmm.batch(rng, b, cfg.seq_len)
        init = args.init_from or os.path.join(
            args.out, "protein_backbone", "ckpt.npz")
        params0, _ = M.load_params(init)
        mask = O.trainable_mask_for_head(params0)
        train_loop("protein_head", cfg, corpus_batch, L.causal_only_loss,
                   args.steps, args.batch, args.out, init_from=init,
                   trainable=mask, seed=args.seed)
    else:
        raise SystemExit(f"unknown task {args.task}")


if __name__ == "__main__":
    main()
