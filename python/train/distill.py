"""SDTT baseline: Self-Distillation Through Time (Deschenaux & Gulcehre 25).

Table 1 compares SSMD against SDTT, whose student achieves very low judge-NLL
at low NFE but with *reduced sample entropy* (mode seeking caused by
truncation errors in the teacher sampling; Zheng et al. 25). We reproduce the
mechanism with the Monte-Carlo variant of SDTT:

  round r: the student is trained so its ONE-step denoising distribution at
  masking level i matches the distribution induced by the round-(r-1) teacher
  taking TWO sampling steps (reveal an intermediate fraction of tokens with
  teacher samples, then re-predict). Revealed intermediate tokens contribute
  one-hot targets, which is where the mode-seeking sharpening comes from.

Only the non-causal (MDM) half of the hybrid checkpoint is distilled; the
student is sampled with the standard MDM algorithm by the rust engine.
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile.config import ModelConfig
from train import data as D
from train import losses as L
from train import optim as O


def make_distill_step(cfg: ModelConfig, reveal_frac: float, lr_kw):
    @functools.partial(jax.jit, donate_argnums=(1, 2))
    def step(teacher, student, opt, x, sigma, n_rev, key):
        B, Dd = x.shape
        masked_tokens, masked = L.apply_masking(cfg, x, sigma, n_rev)
        # Teacher step 1: predict + reveal an intermediate fraction.
        _, t_logits1 = M.draft_forward(teacher, cfg, masked_tokens)
        k1, k2 = jax.random.split(key)
        sampled = jax.random.categorical(k1, t_logits1, axis=-1)
        rank = jnp.argsort(sigma, axis=1)
        m = (Dd - n_rev)
        k_reveal = jnp.maximum(1, (m.astype(jnp.float32) *
                                   reveal_frac).astype(jnp.int32))
        reveal = (rank >= n_rev[:, None]) & (rank < (n_rev + k_reveal)[:, None])
        mid_tokens = jnp.where(reveal, sampled, masked_tokens)
        # Teacher step 2: re-predict on the extended context.
        _, t_logits2 = M.draft_forward(teacher, cfg, mid_tokens)
        t_probs = jax.nn.softmax(t_logits2, axis=-1)
        onehot = jax.nn.one_hot(sampled, cfg.vocab_size)
        target = jnp.where(reveal[..., None], onehot, t_probs)

        def loss_fn(sp):
            _, s_logits = M.draft_forward(sp, cfg, masked_tokens)
            s_logp = jax.nn.log_softmax(s_logits, axis=-1)
            kl = -jnp.sum(target * s_logp, axis=-1)  # CE(target, student)
            w = masked.astype(jnp.float32) / m.astype(jnp.float32)[:, None]
            return jnp.sum(kl * w) / B

        loss, grads = jax.value_and_grad(loss_fn)(student)
        grads, _ = O.clip_by_global_norm(grads, 1.0)
        lr = O.warmup_cosine(opt["t"] + 1, **lr_kw)
        student, opt = O.adam_update(student, grads, opt, lr=lr)
        return student, opt, loss

    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--teacher", default="runs/owt/ckpt.npz")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--reveal-frac", type=float, default=0.5)
    ap.add_argument("--out", default="runs")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    teacher, cfg = M.load_params(args.teacher)
    _, word_chain = D.default_chains()
    corpus = D.WordCorpus(word_chain, cfg.seq_len)
    student = jax.tree_util.tree_map(jnp.array, teacher)
    rng = np.random.default_rng(args.seed + 7)
    key = jax.random.PRNGKey(args.seed)
    t0 = time.time()
    for r in range(args.rounds):
        opt = O.adam_init(student)
        lr_kw = dict(peak_lr=1e-4, warmup=40, total=args.steps)
        step = make_distill_step(cfg, args.reveal_frac, lr_kw)
        for it in range(1, args.steps + 1):
            x = jnp.asarray(corpus.batch(rng, args.batch))
            key, s1, s2 = jax.random.split(key, 3)
            sigma, n_rev = L.sample_masking(s1, cfg, args.batch)
            student, opt, loss = step(teacher, student, opt, x, sigma,
                                      n_rev, s2)
            if it % 50 == 0 or it == args.steps:
                print(f"[sdtt r{r}] {it}/{args.steps} kl={float(loss):.4f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
        teacher = jax.tree_util.tree_map(jnp.array, student)
    os.makedirs(os.path.join(args.out, "sdtt"), exist_ok=True)
    out = os.path.join(args.out, "sdtt", "ckpt.npz")
    M.save_params(out, student, cfg)
    print(f"saved {out}", flush=True)


if __name__ == "__main__":
    main()
