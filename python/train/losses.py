"""Training objectives.

Eq. 9 of the paper: jointly maximize the masked (non-causal, factorized) and
any-order AR (causal) cross-entropies with the D/(D-i) weighting that
normalizes by the number of masked positions. With per-token normalization
the weighted sum over masked positions is exactly the *mean* cross-entropy
over masked positions, which is what we log (nats/token, comparable between
the two components — Fig. 2/6/7).

Conventions follow compile/model.py: draft logits in sequence order, target
logits in track order (track j predicts position sigma[j+1]); ordering
position 0 falls back to the draft distribution (first-position rule), so its
causal loss term equals its non-causal term.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import model as M
from compile.config import ModelConfig


def sample_masking(key, cfg: ModelConfig, batch: int):
    """Sample (sigma, i) per example with the cosine MDM schedule.

    t ~ U(0,1); mask probability alpha_t = cos(pi/2 * (1 - t)); the number of
    masked positions m ~ Binomial(D, alpha_t) clipped to [1, D] (p(i=D)=0).
    Masking the *last m* positions of a uniform sigma is distributionally the
    same as masking each position independently w.p. alpha_t.

    Returns:
      sigma: [B, D] int32 orderings.
      n_revealed: [B] int32, i = D - m.
    """
    D = cfg.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    sigma = jax.vmap(lambda k: jax.random.permutation(k, D))(
        jax.random.split(k1, batch)).astype(jnp.int32)
    t = jax.random.uniform(k2, (batch,))
    alpha = jnp.cos(jnp.pi / 2.0 * (1.0 - t))
    m = jnp.sum(jax.random.uniform(k3, (batch, D)) < alpha[:, None], axis=1)
    m = jnp.clip(m, 1, D).astype(jnp.int32)
    return sigma, D - m


def apply_masking(cfg: ModelConfig, x, sigma, n_revealed):
    """Mask positions sigma(i:D) (0-indexed) with the mask token."""
    B, D = x.shape
    rank = jnp.argsort(sigma, axis=1)  # rank[b, pos] = index of pos in sigma
    masked = rank >= n_revealed[:, None]
    return jnp.where(masked, cfg.mask_id, x), masked


def hybrid_losses(params, cfg: ModelConfig, x, sigma, n_revealed):
    """Per-component mean-over-masked cross entropies (nats/token).

    Returns (loss_noncausal, loss_causal); total Eq. 9 loss = sum.
    """
    B, D = x.shape
    masked_tokens, masked = apply_masking(cfg, x, sigma, n_revealed)
    draft_logits, target_logits = M.hybrid_forward(
        params, cfg, masked_tokens, x, sigma)

    logp_draft = jax.nn.log_softmax(draft_logits, axis=-1)
    nll_draft = -jnp.take_along_axis(
        logp_draft, x[..., None], axis=-1)[..., 0]  # [B, D] seq order

    # Causal: track j predicts position sigma[j+1]. Build per-ordering-
    # position NLL: ordering position d>=1 reads track d-1; position 0 reads
    # the draft NLL of sigma[:, 0].
    logp_tgt = jax.nn.log_softmax(target_logits, axis=-1)
    x_perm = jnp.take_along_axis(x, sigma, axis=1)  # [B, D] ordering order
    x_next = jnp.roll(x_perm, -1, axis=1)
    nll_tracks = -jnp.take_along_axis(
        logp_tgt, x_next[..., None], axis=-1)[..., 0]  # track j -> pos j+1
    nll_causal_ord = jnp.concatenate(
        [jnp.take_along_axis(nll_draft, sigma[:, :1], axis=1),
         nll_tracks[:, :-1]], axis=1)  # [B, D] per ordering position

    rank = jnp.argsort(sigma, axis=1)
    m = (D - n_revealed).astype(jnp.float32)  # number of masked, >= 1
    w_nc = masked.astype(jnp.float32) / m[:, None]
    loss_nc = jnp.sum(nll_draft * w_nc) / B

    ord_idx = jnp.arange(D)[None, :]
    masked_ord = ord_idx >= n_revealed[:, None]
    w_c = masked_ord.astype(jnp.float32) / m[:, None]
    loss_c = jnp.sum(nll_causal_ord * w_c) / B
    return loss_nc, loss_c


def eq9_loss(params, cfg: ModelConfig, x, sigma, n_revealed):
    lnc, lc = hybrid_losses(params, cfg, x, sigma, n_revealed)
    return lnc + lc, (lnc, lc)


def mdm_loss(params, cfg: ModelConfig, x, sigma, n_revealed):
    """Non-causal-only loss (standard MDM objective; backbone pretraining)."""
    masked_tokens, masked = apply_masking(cfg, x, sigma, n_revealed)
    _, draft_logits = M.draft_forward(params, cfg, masked_tokens)
    logp = jax.nn.log_softmax(draft_logits, axis=-1)
    nll = -jnp.take_along_axis(logp, x[..., None], axis=-1)[..., 0]
    B, D = x.shape
    m = (D - n_revealed).astype(jnp.float32)
    w = masked.astype(jnp.float32) / m[:, None]
    loss = jnp.sum(nll * w) / B
    return loss, (loss, jnp.zeros(()))


def causal_only_loss(params, cfg: ModelConfig, x, sigma, n_revealed):
    """Causal-component-only loss (frozen-backbone fine-tuning, Sec. 5.3)."""
    lnc, lc = hybrid_losses(params, cfg, x, sigma, n_revealed)
    return lc, (lnc, lc)
