"""Minimal Adam + schedules (optax is not available in this environment).

Pure-pytree implementation with global-norm clipping, linear warmup + cosine
decay (the paper's schedule, App. G.1), weight decay, and an optional
``trainable`` mask pytree for frozen-backbone fine-tuning (Sec. 5.3).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def adam_init(params) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros,
            "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.0):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, float(warmup))
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, float(total - warmup)),
                    0.0, 1.0)
    cos = floor + (peak_lr - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adam_update(params, grads, state, *, lr, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0,
                trainable: Optional[Any] = None):
    """One Adam step. ``trainable`` is an optional pytree of 0/1 floats with
    the same structure as params; frozen leaves receive zero update."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda vv, g: b2 * vv + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, mm, vv, mask):
        step = lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        step = step + lr * weight_decay * p
        return p - mask * step

    if trainable is None:
        trainable = jax.tree_util.tree_map(lambda p: 1.0, params)
    new_params = jax.tree_util.tree_map(upd, params, m, v, trainable)
    return new_params, {"m": m, "v": v, "t": t}


def trainable_mask_for_head(params) -> Any:
    """Mask pytree freezing everything except the causal half (Sec. 5.3:
    frozen pretrained backbone, train only the added causal block)."""
    causal_keys = {"c_in_w", "c_in_b", "c_blocks", "c_lnf_g", "c_lnf_b"}

    def build(node, path=()):
        if isinstance(node, dict):
            return {k: build(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [build(v, path) for v in node]
        return 1.0 if (path and path[0] in causal_keys) else 0.0

    return build(params)
