"""Model configuration for the hybrid non-causal / causal SSMD transformer.

The config is shared by training (python/train), AOT export (compile/aot.py)
and the pytest suite. It is serialized into artifacts/manifest.json so the
rust coordinator can discover shapes without re-parsing HLO.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of the hybrid SSMD transformer.

    Attributes:
      vocab_size: number of *data* categories S. The mask token id is
        ``vocab_size`` (i.e. M = S + 1 in the paper, 0-indexed here), so the
        embedding table has ``vocab_size + 1`` rows.
      seq_len: D, the (fixed) sequence length.
      hidden: C, residual stream width.
      heads: H, attention heads. ``hidden % heads == 0``.
      ffn: F, feed-forward hidden width.
      n_noncausal: number of non-causal (any-to-any) blocks.
      n_causal: number of sigma-GPT causal blocks (paper: 1 is best).
      residual_out: whether the causal output adds the non-causal hidden of
        the *predicted* position before the head (Fig. 1). Ablation: False.
      dropout: dropout rate (training only; inference graphs are det.).
    """

    vocab_size: int
    seq_len: int
    hidden: int = 64
    heads: int = 4
    ffn: int = 256
    n_noncausal: int = 3
    n_causal: int = 1
    residual_out: bool = True
    dropout: float = 0.0

    @property
    def mask_id(self) -> int:
        return self.vocab_size

    @property
    def n_embed(self) -> int:
        return self.vocab_size + 1

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def n_layers(self) -> int:
        return self.n_noncausal + self.n_causal

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ModelConfig":
        fields = {f.name for f in dataclasses.fields(ModelConfig)}
        return ModelConfig(**{k: v for k, v in d.items() if k in fields})

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "ModelConfig":
        return ModelConfig.from_dict(json.loads(s))


# Preset configs used by the reproduction experiments. Small enough to train
# on the single-core CPU testbed, large enough to exhibit the paper's
# mechanisms (Fig. 2 loss split, Fig. 3/4 NFE-quality trade-off).
def text8_config() -> ModelConfig:
    """Char-level synthetic-text8 model (paper Sec. 5.1: 11nc+1c)."""
    return ModelConfig(vocab_size=27, seq_len=64, hidden=64, heads=4,
                       ffn=256, n_noncausal=3, n_causal=1)


def owt_config(**kw) -> ModelConfig:
    """Word-level synthetic-corpus model (paper Sec. 5.2 analog)."""
    base = dict(vocab_size=256, seq_len=64, hidden=64, heads=4, ffn=256,
                n_noncausal=3, n_causal=1)
    base.update(kw)
    return ModelConfig(**base)


def protein_config(**kw) -> ModelConfig:
    """HMM-protein model (paper Sec. 5.3 analog: frozen backbone + 1 causal)."""
    base = dict(vocab_size=20, seq_len=64, hidden=64, heads=4, ffn=256,
                n_noncausal=4, n_causal=1)
    base.update(kw)
    return ModelConfig(**base)
