"""AOT export: lower trained inference graphs to HLO *text* artifacts.

For each trained checkpoint this emits, per batch-size bucket:

  draft_b{B}.hlo.txt   tokens [B,D] i32 -> (h [B,D,C] f32, logits [B,D,V] f32)
  verify_b{B}.hlo.txt  (h [B,D,C] f32, tokens [B,D] i32, sigma [B,D] i32)
                         -> target logits [B,D,V] f32 (track order)

plus a single ``manifest.json`` the rust coordinator uses for discovery
(model configs, buckets, file names, data-spec files).

HLO **text** is the interchange format, not ``lowered.compiler_ir("hlo")`` /
serialized protos: jax >= 0.5 emits 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). We lower stablehlo -> XlaComputation with
``return_tuple=True`` and the rust side unwraps with ``to_tuple()``.

Weights are baked into the HLO as constants, so the rust binary is fully
self-contained once artifacts are built. Python never runs at serve time.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.config import ModelConfig

# Per-model batch-size buckets. `owt` powers the serving example, so it gets
# the full dynamic-batcher bucket ladder; experiment harnesses sample with a
# single large bucket.
DEFAULT_BUCKETS = {
    "owt": [1, 4, 16],
    "text8": [16],
    "owt_nores": [16],
    "owt_2c": [16],
    "protein_head": [16],
    "sdtt": [16],
}
# SDTT is sampled with the plain MDM algorithm: draft executable only.
DRAFT_ONLY = {"sdtt"}


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False + single-array outputs everywhere: this PJRT
    # client cannot read multi-element tuple literals (see make_draft_fn).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    # as_hlo_text(True) == print_large_constants=True: the baked weights
    # MUST appear in the text or the rust-side parser zero-fills them.
    return comp.as_hlo_text(True)


def golden_outputs(name: str, draft_fn, verify_fn, cfg, has_verify: bool):
    """Deterministic input/output fingerprints for the rust parity test.

    The rust runtime must reproduce these numbers bit-for-bit-ish (f32
    tolerance) when executing the exported HLO — the core L2<->runtime
    correctness signal (tests/pjrt_parity.rs).
    """
    import numpy as np
    D = cfg.seq_len
    rng = np.random.default_rng(20260710)
    tokens = rng.integers(0, cfg.vocab_size, size=(1, D)).astype(np.int32)
    tokens[0, ::3] = cfg.mask_id  # some masked positions
    out = jax.jit(draft_fn)(jnp.asarray(tokens))
    h, logits = out[..., :cfg.hidden], out[..., cfg.hidden:]
    out = {
        "model": name,
        "tokens": tokens[0].tolist(),
        "draft_logits_head": np.asarray(logits)[0, 0, :8].tolist(),
        "draft_logits_mean": float(np.mean(np.asarray(logits))),
        "h_mean": float(np.mean(np.asarray(h))),
    }
    if has_verify:
        full = rng.integers(0, cfg.vocab_size, size=(1, D)).astype(np.int32)
        sigma = rng.permutation(D).astype(np.int32)[None]
        tl = jax.jit(verify_fn)(h, jnp.asarray(full), jnp.asarray(sigma))
        out.update({
            "full_tokens": full[0].tolist(),
            "sigma": sigma[0].tolist(),
            "target_logits_head": np.asarray(tl)[0, 0, :8].tolist(),
            "target_logits_mean": float(np.mean(np.asarray(tl))),
        })
    return out


def export_model(name: str, ckpt_path: str, out_dir: str, buckets):
    params, cfg = M.load_params(ckpt_path)
    D, C = cfg.seq_len, cfg.hidden
    draft_fn = M.make_draft_fn(params, cfg)
    verify_fn = M.make_verify_fn(params, cfg)
    entry = {"config": cfg.to_dict(), "buckets": list(buckets),
             "draft": {}, "verify": {},
             "golden": golden_outputs(name, draft_fn, verify_fn, cfg,
                                      name not in DRAFT_ONLY)}
    for B in buckets:
        tok_spec = jax.ShapeDtypeStruct((B, D), jnp.int32)
        h_spec = jax.ShapeDtypeStruct((B, D, C), jnp.float32)
        sig_spec = jax.ShapeDtypeStruct((B, D), jnp.int32)

        fname = f"{name}_draft_b{B}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(draft_fn, (tok_spec,)))
        entry["draft"][str(B)] = fname
        print(f"  wrote {fname}", flush=True)

        if name not in DRAFT_ONLY:
            fname = f"{name}_verify_b{B}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(to_hlo_text(verify_fn, (h_spec, tok_spec, sig_spec)))
            entry["verify"][str(B)] = fname
            print(f"  wrote {fname}", flush=True)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", default="runs")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_BUCKETS))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": {}, "specs": {}}
    for name in args.models.split(","):
        ckpt = os.path.join(args.runs, name, "ckpt.npz")
        if not os.path.exists(ckpt):
            print(f"skipping {name}: no checkpoint at {ckpt}", flush=True)
            continue
        print(f"exporting {name} from {ckpt}", flush=True)
        manifest["models"][name] = export_model(
            name, ckpt, args.out, DEFAULT_BUCKETS.get(name, [16]))

    # Data-generator specs used by the rust oracle scorers.
    for spec in ("text8_spec.json", "owt_spec.json", "protein_spec.json"):
        src = os.path.join(args.runs, spec)
        if os.path.exists(src):
            shutil.copy(src, os.path.join(args.out, spec))
            manifest["specs"][spec.split("_")[0]] = spec

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest written to {args.out}/manifest.json", flush=True)


if __name__ == "__main__":
    main()
