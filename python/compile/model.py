"""L2: hybrid non-causal / sigma-GPT causal SSMD transformer in JAX.

Implements the architecture of Fig. 1:

* **non-causal stack** — a standard MDM transformer: token + mask embeddings,
  pre-LN blocks with any-to-any attention (L1 Pallas kernel, zero bias) and
  RoPE positions, producing hidden states ``h`` and the factorized *draft*
  distribution over masked positions.
* **causal stack** — sigma-GPT blocks over the *permuted* sequence with a
  causal attention bias and double RoPE (split channels: first half rotated by
  the current ordering position sigma(j), second half by the *next* position
  sigma(j+1), exactly App. G.3). The causal input of track j is a projection
  of [h_perm[j]; h_perm[j+1]; embed(token_perm[j])]. A residual output
  connection adds ``h_perm[j+1]`` (the non-causal hidden of the *predicted*
  position) before the shared output head — the Fig. 1 wiring; disabled by
  ``cfg.residual_out=False`` for the Table 1 ablation.

Conventions (0-indexed, shared with the rust coordinator):
  * mask token id = ``cfg.vocab_size``;
  * ``sigma`` [B, D] is the generation ordering: ``sigma[b, j]`` is the
    sequence position revealed j-th;
  * draft logits are in **sequence-position order** (slot p predicts the
    token at position p);
  * verify logits are in **track order**: track j predicts the token at
    position ``sigma[b, j+1]``; track D-1 wraps around and must not be read
    (ordering position 0's target is the draft distribution — the paper's
    "first position" rule).

Python is build-time only: these functions are trained (python/train) and
AOT-lowered (compile/aot.py) to HLO text executed by the rust runtime.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from compile.config import ModelConfig
from compile.kernels.attention import (causal_bias, masked_flash_attention,
                                       zero_bias)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_block(key, C: int, F: int) -> Params:
    k = jax.random.split(key, 6)
    s = lambda *sh: 1.0 / jnp.sqrt(jnp.asarray(sh[0], jnp.float32))
    return {
        "ln1_g": jnp.ones((C,)), "ln1_b": jnp.zeros((C,)),
        "wq": jax.random.normal(k[0], (C, C)) * s(C),
        "wk": jax.random.normal(k[1], (C, C)) * s(C),
        "wv": jax.random.normal(k[2], (C, C)) * s(C),
        "wo": jax.random.normal(k[3], (C, C)) * s(C),
        "ln2_g": jnp.ones((C,)), "ln2_b": jnp.zeros((C,)),
        "w1": jax.random.normal(k[4], (C, F)) * s(C),
        "b1": jnp.zeros((F,)),
        "w2": jax.random.normal(k[5], (F, C)) * s(F),
        "b2": jnp.zeros((C,)),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    """Initialize the full hybrid model parameter pytree."""
    C, F = cfg.hidden, cfg.ffn
    keys = jax.random.split(key, cfg.n_layers + 3)
    nc = [_init_block(keys[i], C, F) for i in range(cfg.n_noncausal)]
    cb = [_init_block(keys[cfg.n_noncausal + i], C, F)
          for i in range(cfg.n_causal)]
    k_emb, k_out, k_in = keys[-3], keys[-2], keys[-1]
    return {
        "embed": jax.random.normal(k_emb, (cfg.n_embed, C)) * 0.02,
        "out_w": jax.random.normal(k_out, (C, cfg.vocab_size)) / jnp.sqrt(C),
        "out_b": jnp.zeros((cfg.vocab_size,)),
        "nc_blocks": nc,
        "nc_lnf_g": jnp.ones((C,)), "nc_lnf_b": jnp.zeros((C,)),
        # Causal half: input projection of [h_cur; h_next; tok_emb] -> C.
        "c_in_w": jax.random.normal(k_in, (3 * C, C)) / jnp.sqrt(3 * C),
        "c_in_b": jnp.zeros((C,)),
        "c_blocks": cb,
        "c_lnf_g": jnp.ones((C,)), "c_lnf_b": jnp.zeros((C,)),
    }


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _rope_angles(pos, n_freq: int, base: float = 10000.0):
    """pos [..., D] -> angles [..., D, n_freq]."""
    freqs = base ** (-jnp.arange(n_freq, dtype=jnp.float32) / n_freq)
    return pos[..., None].astype(jnp.float32) * freqs


def _apply_rot(x, angles):
    """Rotate channel pairs of x [..., 2*n_freq] by angles [..., n_freq]."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c, s = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def rope_single(x, pos):
    """Standard RoPE: x [B, H, D, dk], pos [B, D] (or [D])."""
    B, H, D, dk = x.shape
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], (B, D))
    ang = _rope_angles(pos, dk // 2)[:, None]  # [B, 1, D, dk/2]
    return _apply_rot(x, ang)


def rope_double(x, pos_cur, pos_next):
    """Split-channel double RoPE (App. G.3).

    First half of head channels rotated by the current ordering position,
    second half by the next position in the ordering.
    """
    B, H, D, dk = x.shape
    xa, xb = jnp.split(x, 2, axis=-1)
    ang_c = _rope_angles(pos_cur, dk // 4)[:, None]
    ang_n = _rope_angles(pos_next, dk // 4)[:, None]
    return jnp.concatenate([_apply_rot(xa, ang_c), _apply_rot(xb, ang_n)],
                           axis=-1)


def _heads(x, H):
    B, D, C = x.shape
    return x.reshape(B, D, H, C // H).transpose(0, 2, 1, 3)


def _unheads(x):
    B, H, D, dk = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, D, H * dk)


def _block(p: Params, x, bias, cfg: ModelConfig, rope_fn):
    """One pre-LN transformer block: attention + MLP, residual stream."""
    h = layer_norm(x, p["ln1_g"], p["ln1_b"])
    q = rope_fn(_heads(h @ p["wq"], cfg.heads))
    k = rope_fn(_heads(h @ p["wk"], cfg.heads))
    v = _heads(h @ p["wv"], cfg.heads)
    a = masked_flash_attention(q, k, v, bias)
    x = x + _unheads(a) @ p["wo"]
    h = layer_norm(x, p["ln2_g"], p["ln2_b"])
    x = x + jax.nn.gelu(h @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]
    return x


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def noncausal_hiddens(params: Params, cfg: ModelConfig, tokens):
    """Non-causal stack: tokens [B, D] (mask id allowed) -> h [B, D, C]."""
    B, D = tokens.shape
    x = params["embed"][tokens]
    bias = zero_bias(D)
    pos = jnp.arange(D)
    rope_fn = lambda t: rope_single(t, pos)
    for p in params["nc_blocks"]:
        x = _block(p, x, bias, cfg, rope_fn)
    return layer_norm(x, params["nc_lnf_g"], params["nc_lnf_b"])


def head_logits(params: Params, h):
    """Shared output head: hiddens -> logits over the data vocabulary."""
    return h @ params["out_w"] + params["out_b"]


def draft_forward(params: Params, cfg: ModelConfig, tokens):
    """Full draft pass: tokens -> (h, draft_logits in sequence order)."""
    h = noncausal_hiddens(params, cfg, tokens)
    return h, head_logits(params, h)


def verify_forward(params: Params, cfg: ModelConfig, h, tokens, sigma):
    """Causal verify pass.

    Args:
      h: [B, D, C] non-causal hiddens (from ``noncausal_hiddens`` run on the
        *masked* context — the theta(x^sigma(1:i)) conditioning).
      tokens: [B, D] full token sequence in sequence order: real revealed
        values where revealed, draft values elsewhere. No mask tokens.
      sigma: [B, D] int32 generation ordering.

    Returns:
      [B, D, V] target logits in **track order**: track j predicts the token
      at sequence position ``sigma[b, j+1]``; track D-1 is wrap-around filler.
    """
    B, D = tokens.shape
    hp = jnp.take_along_axis(h, sigma[..., None], axis=1)
    tokp = jnp.take_along_axis(tokens, sigma, axis=1)
    hp_next = jnp.roll(hp, -1, axis=1)
    sig_next = jnp.roll(sigma, -1, axis=1)
    emb = params["embed"][tokp]
    x = jnp.concatenate([hp, hp_next, emb], axis=-1) @ params["c_in_w"] \
        + params["c_in_b"]
    bias = causal_bias(D)
    rope_fn = lambda t: rope_double(t, sigma, sig_next)
    for p in params["c_blocks"]:
        x = _block(p, x, bias, cfg, rope_fn)
    x = layer_norm(x, params["c_lnf_g"], params["c_lnf_b"])
    if cfg.residual_out:
        # Fig. 1 output residual: add the non-causal hidden state of the
        # position being predicted. Aligns draft and target distributions.
        x = x + hp_next
    return head_logits(params, x)


def hybrid_forward(params: Params, cfg: ModelConfig, masked_tokens,
                   full_tokens, sigma):
    """Training-path forward: one pass producing draft AND target logits."""
    h, draft_logits = draft_forward(params, cfg, masked_tokens)
    target_logits = verify_forward(params, cfg, h, full_tokens, sigma)
    return draft_logits, target_logits


# ---------------------------------------------------------------------------
# Inference graphs for AOT export (closed over trained params)
# ---------------------------------------------------------------------------

def make_draft_fn(params: Params, cfg: ModelConfig):
    """tokens [B, D] i32 -> concat([h, logits], -1) as [B, D, C+V] f32.

    Single-array output: the image's PJRT client (xla_extension 0.5.1 via
    the rust `xla` crate) does not untuple multi-output roots, and
    multi-element tuple literals read back zeroed. The rust runtime splits
    the last axis back into (h [B,D,C], logits [B,D,V]).
    """

    def fn(tokens):
        h, logits = draft_forward(params, cfg, tokens)
        return jnp.concatenate(
            [h.astype(jnp.float32), logits.astype(jnp.float32)], axis=-1)

    return fn


def make_verify_fn(params: Params, cfg: ModelConfig):
    """(h, tokens, sigma) -> target logits [B, D, V] in track order."""

    def fn(h, tokens, sigma):
        return verify_forward(params, cfg, h, tokens, sigma).astype(
            jnp.float32)

    return fn


# ---------------------------------------------------------------------------
# Parameter (de)serialization — npz with flattened path keys
# ---------------------------------------------------------------------------

def flatten_params(params: Params, prefix: str = "") -> Dict[str, Any]:
    flat = {}
    if isinstance(params, dict):
        for k, v in params.items():
            flat.update(flatten_params(v, f"{prefix}{k}/"))
    elif isinstance(params, (list, tuple)):
        for i, v in enumerate(params):
            flat.update(flatten_params(v, f"{prefix}{i}/"))
    else:
        flat[prefix[:-1]] = params
    return flat


def unflatten_params(flat: Dict[str, Any]) -> Params:
    root: Dict[str, Any] = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(v)

    def listify(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return [listify(node[str(i)]) for i in range(len(keys))]
        return {k: listify(v) for k, v in node.items()}

    return listify(root)


def save_params(path: str, params: Params, cfg: ModelConfig) -> None:
    import numpy as np
    flat = {k: np.asarray(v) for k, v in flatten_params(params).items()}
    flat["__config__"] = np.frombuffer(
        cfg.to_json().encode("utf-8"), dtype=np.uint8)
    np.savez(path, **flat)


def load_params(path: str):
    import numpy as np
    data = dict(np.load(path))
    cfg = ModelConfig.from_json(
        bytes(data.pop("__config__").tobytes()).decode("utf-8"))
    return unflatten_params(data), cfg


def param_count(params: Params) -> int:
    return sum(int(v.size) for v in flatten_params(params).values())
