"""L1 Pallas kernel: fused masked flash-attention.

This is the compute hot-spot of both halves of the hybrid SSMD transformer:

* the non-causal draft stack uses **any-to-any** attention (zero bias);
* the sigma-GPT causal verify block uses a **causal** bias applied to the
  permuted sequence.

One kernel serves both: QK^T -> additive bias -> online (flash-style) softmax
-> V, tiled over (batch, head, query-block) with a running (max, sum, acc)
carried across key blocks so only (block_q x block_k) score tiles ever live in
VMEM.

Hardware adaptation (DESIGN.md "Hardware-Adaptation"): the paper's models run
on TPU; we tile for VMEM via BlockSpecs (q/k blocks of 64, f32 accumulation)
and keep the two matmuls MXU-shaped. ``interpret=True`` is mandatory on this
CPU testbed — real-TPU lowering emits a Mosaic custom-call the CPU PJRT
plugin cannot execute — so the kernel is validated for *correctness* here and
its TPU efficiency is estimated analytically in DESIGN.md / EXPERIMENTS.md
(Perf section).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, block_k: int,
                 scale: float, kv_len: int):
    """Pallas kernel body for one (batch*head, q-block) grid cell.

    Refs:
      q_ref:    [block_q, dk]   query tile (VMEM)
      k_ref:    [kv_len, dk]    full keys for this head (VMEM)
      v_ref:    [kv_len, dk]    full values for this head (VMEM)
      bias_ref: [block_q, kv_len] additive bias tile (VMEM)
      o_ref:    [block_q, dk]   output tile (VMEM)
    """
    q = q_ref[...].astype(jnp.float32) * scale
    block_q, dk = q.shape
    n_kb = kv_len // block_k

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        b = bias_ref[:, pl.dslice(kb * block_k, block_k)].astype(jnp.float32)
        s = q @ k.T + b  # [block_q, block_k]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Rescale previous accumulator; accumulate current block.
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dk), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    # Guard against a degenerate all-underflow row (the finite sentinel
    # bias keeps l > 0 in practice; ref.py mirrors this guard).
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _pick_block(n: int, pref: int) -> int:
    """Largest divisor of ``n`` that is <= pref (VMEM-friendly tile size)."""
    b = min(pref, n)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _attention_vjp(q, k, v, bias, block_q, block_k):
    return _attention_impl(q, k, v, bias, block_q, block_k)


def _attention_fwd(q, k, v, bias, block_q, block_k):
    o = _attention_impl(q, k, v, bias, block_q, block_k)
    return o, (q, k, v, bias)


def _attention_bwd(block_q, block_k, res, do):
    """Analytic attention backward (pallas_call has no autodiff rule in
    interpret mode; training recomputes probabilities in pure jnp — the
    standard flash-attention recompute strategy)."""
    q, k, v, bias = res
    B, H, D, dk = q.shape
    scale = 1.0 / math.sqrt(dk)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale + bias[None, None]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    l = jnp.where(l == 0.0, 1.0, l)
    p = p / l
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf) * scale
    dk_ = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
    dbias = jnp.sum(ds, axis=(0, 1))
    return (dq.astype(q.dtype), dk_.astype(k.dtype), dv.astype(v.dtype),
            dbias.astype(bias.dtype))


_attention_vjp.defvjp(_attention_fwd, _attention_bwd)


def masked_flash_attention(q, k, v, bias, *, block_q: int = 64,
                           block_k: int = 64):
    """Public entry point (differentiable). See `_attention_impl`."""
    return _attention_vjp(q, k, v, bias, block_q, block_k)


def _attention_impl(q, k, v, bias, block_q: int = 64, block_k: int = 64):
    """Fused attention with an additive bias shared across batch and heads.

    Args:
      q, k, v: [B, H, D, dk] arrays (any float dtype; accumulated in f32).
      bias: [D, D] additive attention bias (0 = attend, -inf = masked).
      block_q, block_k: tile sizes; rounded down to divisors of D.

    Returns:
      [B, H, D, dk] attention output, dtype of ``q``.
    """
    B, H, D, dk = q.shape
    assert k.shape == (B, H, D, dk) and v.shape == (B, H, D, dk)
    assert bias.shape == (D, D), bias.shape
    bq = _pick_block(D, block_q)
    bk = _pick_block(D, block_k)
    scale = 1.0 / math.sqrt(dk)

    kernel = functools.partial(_attn_kernel, block_k=bk, scale=scale,
                               kv_len=D)
    qf = q.reshape(B * H, D, dk)
    kf = k.reshape(B * H, D, dk)
    vf = v.reshape(B * H, D, dk)
    grid = (B * H, D // bq)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, dk), lambda bh, qb: (bh, qb, 0)),
            pl.BlockSpec((None, D, dk), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((None, D, dk), lambda bh, qb: (bh, 0, 0)),
            pl.BlockSpec((bq, D), lambda bh, qb: (qb, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dk), lambda bh, qb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, D, dk), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(qf, kf, vf, bias)
    return out.reshape(B, H, D, dk)


def causal_bias(D: int) -> jnp.ndarray:
    """Standard lower-triangular causal additive bias [D, D]."""
    i = jnp.arange(D)
    return jnp.where(i[:, None] >= i[None, :], 0.0, NEG_INF).astype(jnp.float32)


def zero_bias(D: int) -> jnp.ndarray:
    """Any-to-any (non-causal) bias: all zeros."""
    return jnp.zeros((D, D), dtype=jnp.float32)


def vmem_footprint_bytes(D: int, dk: int, block_q: int = 64,
                         block_k: int = 64, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid cell (perf-model input).

    q tile + k/v residents + bias tile + score tile + accumulator.
    Used by the Perf section to check we stay under ~16 MiB/core VMEM and to
    estimate MXU utilization on a hypothetical TPU deployment.
    """
    bq = _pick_block(D, block_q)
    bk = _pick_block(D, block_k)
    q_t = bq * dk
    kv = 2 * D * dk
    bias_t = bq * D
    score = bq * bk
    acc = bq * dk + 2 * bq
    return (q_t + kv + bias_t + score + acc) * dtype_bytes
