"""Pure-jnp correctness oracle for the Pallas attention kernel.

Deliberately naive: materializes the full [B, H, D, D] score tensor and uses
plain softmax math. Every numerical choice (f32 accumulation, scale, bias
semantics, fully-masked-row -> zeros) mirrors the kernel contract so that
``assert_allclose(kernel, ref)`` is the core L1 correctness signal.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def reference_attention(q, k, v, bias):
    """Naive softmax attention. Same signature/semantics as the kernel.

    Args:
      q, k, v: [B, H, D, dk].
      bias: [D, D] additive bias.
    Returns:
      [B, H, D, dk] in the dtype of q.
    """
    B, H, D, dk = q.shape
    scale = 1.0 / math.sqrt(dk)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = s + bias.astype(jnp.float32)[None, None]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    # Degenerate all-underflow guard, mirroring the kernel.
    l = jnp.where(l == 0.0, 1.0, l)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / l, v.astype(jnp.float32))
    return o.astype(q.dtype)
