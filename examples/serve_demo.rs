//! End-to-end serving driver (DESIGN.md "End-to-end validation").
//!
//! Boots the full stack — PJRT runtime, engine thread, dynamic batcher,
//! HTTP server — then fires concurrent client requests over real TCP and
//! reports latency percentiles and throughput. Proves all layers compose:
//! L1/L2 artifacts -> runtime -> coordinator -> server -> clients.
//!
//!   cargo run --release --example serve_demo -- --artifacts artifacts \
//!       --model owt --clients 4 --requests 16

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};
use ssmd::coordinator::{BatcherConfig, Coordinator};
use ssmd::server::Server;
use ssmd::util::args::Args;
use ssmd::util::bench::fmt_duration;
use ssmd::util::json::Json;

fn http_post(addr: &str, path: &str, body: &str) -> Result<String> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_read_timeout(Some(Duration::from_secs(120)))?;
    write!(
        conn,
        "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )?;
    let mut out = String::new();
    conn.read_to_string(&mut out)?;
    let (head, body) = out
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("bad response"))?;
    if !head.starts_with("HTTP/1.1 200") {
        return Err(anyhow!("{head}\n{body}"));
    }
    Ok(body.to_string())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let model = args.str("model", "owt");
    let n_clients = args.usize("clients", 4);
    let reqs_per_client = args.usize("requests", 8);
    let addr = args.str("addr", "127.0.0.1:47711");

    // ---- boot the full stack -------------------------------------------
    let coordinator = Coordinator::start(
        {
            let artifacts = artifacts.clone();
            let model = model.clone();
            move || {
                let manifest = ssmd::runtime::Manifest::load(&artifacts)?;
                let runtime = ssmd::runtime::Runtime::cpu()?;
                let mut map = ssmd::coordinator::ModelMap::new();
                map.insert(
                    model.clone(),
                    Box::new(runtime.load_model(manifest.model(&model)?)?)
                        as Box<dyn ssmd::coordinator::EngineModel>,
                );
                Ok(map)
            }
        },
        BatcherConfig {
            max_wait: Duration::from_millis(10),
            ..Default::default()
        },
    )?;
    let metrics = coordinator.metrics.clone();
    let server = Server::new(coordinator);
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let addr2 = addr.clone();
    let server_handle = std::thread::spawn(move || {
        let stopped = move || stop2.load(Ordering::Relaxed);
        if let Err(e) = server.serve_until(&addr2, stopped) {
            eprintln!("server exited with error: {e}");
        }
    });
    // lint: allow(clock-discipline) — real TCP demo: give the OS a
    // beat to bind the listener before clients connect.
    std::thread::sleep(Duration::from_millis(100));

    // ---- hammer it -------------------------------------------------------
    // Client issue/response handling is a request-admission path: it
    // must report failures, not panic (repolint serve-no-unwrap).
    // lint: serve-region
    // lint: allow(clock-discipline) — operator-facing wall-clock
    // throughput for a live TCP run; no scheduler reads it.
    let started = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let model = model.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut lat = Vec::new();
            for r in 0..reqs_per_client {
                let body = format!(
                    r#"{{"model":"{model}","n":1,"sampler":"speculative",
                        "window":"cosine:0.05","n_verify":2,
                        "seed":{}}}"#,
                    c * 1000 + r
                );
                // lint: allow(clock-discipline) — client-observed
                // latency over real TCP is wall time by definition.
                let t = Instant::now();
                let resp = http_post(&addr, "/generate", &body)?;
                lat.push(t.elapsed().as_secs_f64());
                let v = Json::parse(&resp).map_err(|e| anyhow!("{e}"))?;
                let n = v
                    .get("samples")
                    .and_then(|s| s.as_arr())
                    .map(|s| s.len())
                    .unwrap_or(0);
                if n != 1 {
                    return Err(anyhow!("unexpected sample count: {n}"));
                }
            }
            Ok(lat)
        }));
    }
    let mut latencies = Vec::new();
    for h in handles {
        let lat = h.join().map_err(|_| anyhow!("client thread panicked"))?;
        latencies.extend(lat?);
    }
    let wall = started.elapsed().as_secs_f64();
    // lint: end-serve-region

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let total = latencies.len();
    let pct = |q: f64| latencies[((total as f64 * q) as usize).min(total - 1)];
    println!("\n=== serve_demo results ===");
    println!("requests: {total} over {n_clients} clients");
    println!("wall: {:.2}s  throughput: {:.2} req/s  ({:.1} tok/s)",
             wall,
             total as f64 / wall,
             total as f64 * 64.0 / wall);
    println!("latency p50 {}  p90 {}  p99 {}",
             fmt_duration(pct(0.50)),
             fmt_duration(pct(0.90)),
             fmt_duration(pct(0.99)));

    // ---- metrics endpoint over HTTP (observability path) -----------------
    let m = http_post(&addr, "/score", "{}").err(); // expected 400, warm path
    let _ = m;
    let snap = metrics.snapshot();
    println!("\nserver metrics snapshot:");
    println!("{snap}");

    stop.store(true, Ordering::Relaxed);
    if server_handle.join().is_err() {
        return Err(anyhow!("server thread panicked during shutdown"));
    }
    Ok(())
}
