//! Propositions 3.1 and C.2 on a real checkpoint: exact sample likelihood
//! under Algorithm 2 and the posterior over rejection counts (= forward
//! passes - 1), computed with D draft + D verify passes and O(D^2) math.
//!
//!   cargo run --release --example likelihood_demo -- --artifacts artifacts \
//!       --model owt

use anyhow::Result;
use ssmd::coordinator::EngineModel;
use ssmd::engine::{Prompt, SpecParams, Window};
use ssmd::harness;
use ssmd::likelihood::{log_likelihood, rejection_posterior, SpecTable};
use ssmd::util::args::Args;
use ssmd::util::rng::Pcg;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let model_name = args.str("model", "owt");
    let (_rt, _m, models) = harness::load_models(&artifacts, &[&model_name])?;
    let model = &models[&model_name];
    let d = EngineModel::seq_len(model);

    // Draw one sample with Algorithm 2 (unbounded window, 1 verify/draft)
    // under a fixed ordering, then evaluate its exact likelihood.
    let mut rng = Pcg::new(args.u64("seed", 1));
    let sigma = rng.permutation(d);
    let params = SpecParams {
        window: Window::Constant(d),
        n_verify: 1,
        sigma: Some(sigma.clone()),
        ..Default::default()
    };
    let (samples, stats) = ssmd::engine::speculative_sample(
        model, &[Prompt::empty(d)], &params, &mut rng);
    let s = &samples[0];
    println!("sampled sequence (D={d}): {:?}...",
             &s.tokens[..12.min(d)]);
    println!("sampler observed: {} rejections, {:.1} NFE",
             s.rejected, s.nfe);
    println!("batch stats: {stats:?}\n");

    println!("building Prop 3.1 table ({d} draft + {d} verify passes)...");
    let table = SpecTable::from_model(model, &s.tokens, &sigma);
    let ll = log_likelihood(&table);
    println!("log p(x | sigma)      = {:.3} nats ({:.4} nats/token)",
             ll, ll / d as f64);

    let post = rejection_posterior(&table);
    let mean_n: f64 =
        post.iter().enumerate().map(|(n, p)| n as f64 * p).sum();
    let mode = post
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(n, _)| n)
        .unwrap_or(0);
    println!("rejection posterior (Prop C.2): E[N | x] = {mean_n:.2}, \
              mode = {mode}");
    println!("  -> expected forward passes for this x: {:.2}", mean_n + 1.0);
    let shown: Vec<String> = post
        .iter()
        .enumerate()
        .filter(|(_, p)| **p > 5e-3)
        .map(|(n, p)| format!("p(N={n})={p:.3}"))
        .collect();
    println!("  {}", shown.join("  "));

    // Draft-only (factorized) likelihood of the same sequence for contrast:
    // the non-factorized sampler distribution should assign it more mass.
    let draft_ll: f64 = (0..d).map(|dd| table.p[0][dd].ln()).sum();
    println!("\nfactorized one-shot draft log-likelihood = {:.3} nats \
              (speculative model: {:.3})", draft_ll, ll);
    Ok(())
}
