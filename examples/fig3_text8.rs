//! Figure 3: spelling accuracy vs NFE on (synthetic) text8 —
//! speculative sampling vs standard masked diffusion.
//!
//! Sweeps the paper's Table 3 settings (draft/verify steps per non-causal
//! pass x cosine-window dtau) for our method and a timestep sweep for the
//! MDM baseline (the draft half of the same checkpoint, sampled with the
//! standard algorithm — best-case NFE counting for a strong baseline).
//!
//!   cargo run --release --example fig3_text8 -- --artifacts artifacts \
//!       --samples 128

use anyhow::Result;
use ssmd::harness::{self, fmt_f, mdm_sweep, spec_sweep, Table};
use ssmd::oracle::{spelling_accuracy, unigram_entropy, BigramOracle};
use ssmd::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let n_samples = args.usize("samples", 128);
    let seed = args.u64("seed", 0);

    let (_rt, manifest, models) =
        harness::load_models(&artifacts, &["text8"])?;
    let model = &models["text8"];
    let d = ssmd::coordinator::EngineModel::seq_len(model);
    let spec_path = manifest
        .specs
        .get("text8")
        .expect("text8 spec in manifest");
    let oracle = BigramOracle::from_spec_file(spec_path.to_str().unwrap())?;

    // Paper Table 3 settings (n_verify, dtau).
    let settings: &[(usize, f64)] = &[
        (1, 0.01),
        (1, 0.02),
        (1, 0.04),
        (1, 0.083),
        (2, 0.083),
        (3, 0.125),
        (4, 0.167),
    ];
    println!("# Figure 3 — spelling accuracy vs NFE (synthetic text8, \
              D={d}, {n_samples} samples/point)\n");

    let mut table = Table::new(&["method", "setting", "NFE", "accuracy",
                                 "entropy", "accept%"]);
    let spec_points = spec_sweep(model, settings, n_samples,
                                 seed)?;
    for p in &spec_points {
        let acc = spelling_accuracy(&p.samples, d, &oracle.lexicon);
        table.row(vec![
            "speculative".into(),
            p.label.clone(),
            fmt_f(p.nfe, 1),
            fmt_f(acc, 3),
            fmt_f(unigram_entropy(&p.samples, d), 3),
            fmt_f(100.0 * p.accept_rate, 1),
        ]);
    }
    let mdm_steps = [4usize, 8, 12, 16, 24, 32, 48, 64];
    let mdm_points = mdm_sweep(model, &mdm_steps, n_samples,
                               seed + 1)?;
    for p in &mdm_points {
        let acc = spelling_accuracy(&p.samples, d, &oracle.lexicon);
        table.row(vec![
            "mdm".into(),
            p.label.clone(),
            fmt_f(p.nfe, 1),
            fmt_f(acc, 3),
            fmt_f(unigram_entropy(&p.samples, d), 3),
            "-".into(),
        ]);
    }
    table.print();

    // Headline: NFE reduction at matched accuracy (the paper's ~2x claim).
    let spec_curve: Vec<(f64, f64)> = spec_points
        .iter()
        .map(|p| (p.nfe, spelling_accuracy(&p.samples, d, &oracle.lexicon)))
        .collect();
    let mdm_curve: Vec<(f64, f64)> = mdm_points
        .iter()
        .map(|p| (p.nfe, spelling_accuracy(&p.samples, d, &oracle.lexicon)))
        .collect();
    if let Some(f) = ssmd::harness::nfe_reduction(&spec_curve, &mdm_curve) {
        println!("\nheadline: ~{:.2}x NFE reduction at matched accuracy \
                  (paper: ~2x)", f);
    }
    Ok(())
}
