//! Appendix E: FLOP overhead of the self-speculative architecture.
//! Regenerates every number of the appendix (paper OWT settings) and the
//! 0.98% headline, plus a sweep over model scales showing the overhead
//! shrinks as models grow.
//!
//!   cargo run --release --example flops_analysis

use ssmd::flops::TransformerShape;
use ssmd::harness::{fmt_f, Table};

fn main() {
    let t = TransformerShape::paper_owt();
    println!("# Appendix E — FLOP analysis (C=768 V=50257 K=64 H=12 \
              F=3072 S=1024 L=12)\n");
    let mut table = Table::new(&["component", "FLOPs", "paper"]);
    let rows: Vec<(&str, u64, &str)> = vec![
        ("embedding", t.embedding(), "7.9e10"),
        ("qkv projection", t.qkv_projection(), "3.6e9"),
        ("k@q", t.kq_matmul(), "1.6e9"),
        ("softmax", t.softmax(), "3.7e7"),
        ("softmax@query reduction", t.softmax_query_reduction(), "1.6e9"),
        ("linear", t.attn_linear(), "1.2e9"),
        ("attention total", t.attention(), "8e9"),
        ("dense block", t.dense_block(), "9.7e9"),
        ("final logits", t.final_logits(), "7.9e10"),
        ("TOTAL vanilla", t.total_vanilla(), "3.7e11"),
        ("speculative overhead", t.speculative_overhead(), "3.6e9"),
    ];
    for (name, v, paper) in rows {
        table.row(vec![name.into(), format!("{:.3e}", v as f64),
                       paper.into()]);
    }
    table.print();
    println!(
        "\noverhead fraction = {}% (paper: 0.98%)",
        fmt_f(100.0 * t.overhead_fraction(), 2)
    );

    println!("\n## Scale sweep (overhead dilutes with width)\n");
    let mut sweep = Table::new(&["C", "layers", "overhead %"]);
    for (c, layers) in [(256u64, 6u64), (768, 12), (1536, 24), (4096, 32)] {
        let s = TransformerShape {
            c,
            f: 4 * c,
            h: c / 64,
            k: 64,
            v: 50_257,
            s: 1024,
            layers,
        };
        sweep.row(vec![
            format!("{c}"),
            format!("{layers}"),
            fmt_f(100.0 * s.overhead_fraction(), 3),
        ]);
    }
    sweep.print();
}
