//! Bench trend gate: diff the working directory's `BENCH_*.json`
//! artifacts against the committed baseline snapshot and exit nonzero on
//! a >20% mean regression (or a benchmark that disappeared).
//!
//!   cargo bench                      # produce BENCH_*.json
//!   cargo run --example bench_trend  # gate against benchmarks/baseline/
//!
//! Flags: `--baseline DIR` (default benchmarks/baseline), `--current DIR`
//! (default .), `--threshold 0.20`.
//!
//! Wall-clock comparisons only gate when *neither* side is a smoke run
//! (`BENCH_SMOKE=1` emits `smoke:true` artifacts — structure and the
//! deterministic `extra` counters still diff, timings don't). Seed or
//! refresh the baseline from a full run:
//!
//!   cargo bench && mkdir -p benchmarks/baseline \
//!     && cp BENCH_*.json benchmarks/baseline/

use std::path::PathBuf;
use std::process::exit;

use ssmd::util::args::Args;
use ssmd::util::bench::fmt_duration;
use ssmd::util::benchdiff::{diff, load};

fn main() {
    let args = Args::from_env();
    let baseline_dir =
        PathBuf::from(args.str("baseline", "benchmarks/baseline"));
    let current_dir = PathBuf::from(args.str("current", "."));
    let threshold = args.f64("threshold", 0.20);

    let mut artifacts: Vec<PathBuf> = match std::fs::read_dir(&current_dir)
    {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect(),
        Err(e) => {
            eprintln!("cannot read {}: {e}", current_dir.display());
            exit(2);
        }
    };
    artifacts.sort();
    if artifacts.is_empty() {
        eprintln!(
            "no BENCH_*.json in {} — run `cargo bench` (or \
             `BENCH_SMOKE=1 cargo bench`) first",
            current_dir.display()
        );
        exit(2);
    }

    let mut failed = false;
    // A baseline artifact with no current counterpart means a whole
    // bench target vanished — that must fail, not be silently skipped.
    if let Ok(rd) = std::fs::read_dir(&baseline_dir) {
        for entry in rd.filter_map(|e| e.ok()) {
            let name = entry.file_name().to_string_lossy().to_string();
            if name.starts_with("BENCH_")
                && name.ends_with(".json")
                && !artifacts.iter().any(|p| {
                    p.file_name().and_then(|n| n.to_str())
                        == Some(name.as_str())
                })
            {
                eprintln!(
                    "FAIL baseline {name} has no current artifact — did \
                     a bench target vanish? (re-run cargo bench, or \
                     remove the baseline file intentionally)"
                );
                failed = true;
            }
        }
    }
    for path in artifacts {
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let base_path = baseline_dir.join(&name);
        if !base_path.exists() {
            println!(
                "{name}: no committed baseline — skipped (seed one: \
                 cargo bench && cp {name} {}/)",
                baseline_dir.display()
            );
            continue;
        }
        let (base, cur) = match (load(&base_path), load(&path)) {
            (Ok(b), Ok(c)) => (b, c),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("{name}: {e}");
                failed = true;
                continue;
            }
        };
        let rep = match diff(&base, &cur) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{name}: {e}");
                failed = true;
                continue;
            }
        };

        println!("== {name} (target '{}') ==", rep.target);
        if !rep.comparable() {
            println!(
                "  smoke artifact on {} side: structural + extras check \
                 only, timings not gated",
                if rep.cur_smoke && rep.base_smoke {
                    "both"
                } else if rep.cur_smoke {
                    "the current"
                } else {
                    "the baseline"
                }
            );
        }
        for d in &rep.deltas {
            let pct = d.change() * 100.0;
            if rep.comparable() {
                println!(
                    "  {:<44} {:>10} -> {:>10}  {:+6.1}%",
                    d.name,
                    fmt_duration(d.base),
                    fmt_duration(d.cur),
                    pct
                );
            }
        }
        for d in &rep.extra_deltas {
            println!(
                "  extra {:<38} {:>10.4} -> {:>10.4}  {:+6.1}%",
                d.name,
                d.base,
                d.cur,
                d.change() * 100.0
            );
        }
        for n in &rep.new_in_current {
            println!("  new bench (no baseline yet): {n}");
        }
        for n in &rep.missing_extras {
            println!(
                "  extra '{n}' only in baseline (not emitted this run — \
                 expected for timing-derived extras under smoke)"
            );
        }
        for n in &rep.missing_in_current {
            eprintln!("  FAIL missing bench (present in baseline): {n}");
            failed = true;
        }
        let regs = rep.regressions(threshold);
        for d in &regs {
            eprintln!(
                "  FAIL {}: mean {} -> {} ({:+.1}% > {:.0}%)",
                d.name,
                fmt_duration(d.base),
                fmt_duration(d.cur),
                d.change() * 100.0,
                threshold * 100.0
            );
        }
        if !regs.is_empty() {
            failed = true;
        }
    }
    exit(if failed { 1 } else { 0 });
}
