//! Figures 2, 6, 7: training-loss curves split into the non-causal (draft)
//! and causal (target) components of Eq. 9.
//!
//! Reads the CSV loss logs written by python/train/train.py and summarizes
//! the paper's qualitative claims: the two components track each other
//! early (the output residual initializes the target at the draft), then
//! the causal component drops *below* the non-causal one as the causal
//! block learns to exploit the extra revealed context — the capacity gap
//! speculative sampling then converts into fewer NFE.
//!
//!   cargo run --release --example fig2_losses -- --runs python/runs

use anyhow::Result;
use ssmd::harness::{fmt_f, Table};
use ssmd::util::args::Args;

struct Run {
    name: &'static str,
    figure: &'static str,
    csv: String,
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let runs = args.str("runs", "python/runs");
    let candidates = [
        Run { name: "text8", figure: "Fig. 2",
              csv: format!("{runs}/text8/losses.csv") },
        Run { name: "owt", figure: "Fig. 6",
              csv: format!("{runs}/owt/losses.csv") },
        Run { name: "protein_head (frozen backbone)", figure: "Fig. 7",
              csv: format!("{runs}/protein_head/losses.csv") },
    ];

    for run in &candidates {
        let Ok(text) = std::fs::read_to_string(&run.csv) else {
            println!("({}: no loss log at {}, skipping)", run.name, run.csv);
            continue;
        };
        let mut rows: Vec<(usize, f64, f64)> = Vec::new();
        for line in text.lines().skip(1) {
            let mut f = line.split(',');
            let step: usize = f.next().unwrap_or("0").parse().unwrap_or(0);
            let nc: f64 = f.next().unwrap_or("0").parse().unwrap_or(0.0);
            let c: f64 = f.next().unwrap_or("0").parse().unwrap_or(0.0);
            rows.push((step, nc, c));
        }
        if rows.is_empty() {
            continue;
        }
        println!("\n# {} — {} training losses ({} log points)", run.figure,
                 run.name, rows.len());
        let mut t = Table::new(&["step", "non-causal", "causal",
                                 "gap (nc - c)"]);
        // Print ~8 evenly spaced checkpoints.
        let stride = (rows.len() / 8).max(1);
        for (i, (step, nc, c)) in rows.iter().enumerate() {
            if i % stride == 0 || i == rows.len() - 1 {
                t.row(vec![
                    format!("{step}"),
                    fmt_f(*nc, 4),
                    fmt_f(*c, 4),
                    fmt_f(nc - c, 4),
                ]);
            }
        }
        t.print();
        let early = &rows[..(rows.len() / 5).max(1)];
        let late = &rows[rows.len() * 4 / 5..];
        let mean =
            |xs: &[(usize, f64, f64)], f: fn(&(usize, f64, f64)) -> f64| {
                xs.iter().map(f).sum::<f64>() / xs.len() as f64
            };
        let early_gap = mean(early, |r| r.1 - r.2);
        let late_gap = mean(late, |r| r.1 - r.2);
        println!(
            "early mean gap {:+.4} nats -> late mean gap {:+.4} nats \
             (paper: gap opens as the causal block learns non-factorized \
             structure)",
            early_gap, late_gap
        );
    }
    Ok(())
}
