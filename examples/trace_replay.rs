//! Record a live run's arrivals/costs as a JSONL trace and replay it
//! deterministically through the virtual-time sim harness (`ssmd::sim`).
//!
//!   # Replay a trace twice; exit nonzero unless the replays are
//!   # bitwise-stable (steps/sheds/violations/preemptions/tokens):
//!   cargo run --example trace_replay -- --replay benchmarks/traces/smoke.jsonl
//!
//!   # Fleet replay: N replicas on one shared virtual clock, honouring
//!   # any replica-kill script recorded in the trace. With
//!   # --expect-faults the run fails unless a kill actually evacuated
//!   # checkpoints, the victim restarted under supervision, and every
//!   # admitted sequence still finished:
//!   cargo run --example trace_replay -- \
//!       --replay benchmarks/traces/fleet_kill.jsonl --engines 2 \
//!       --expect-faults
//!
//!   # Record a synthetic live workload against a real Coordinator
//!   # (MockModels, wall clock), assemble the event stream into a
//!   # trace, write it, and validate it replays:
//!   cargo run --example trace_replay -- --record /tmp/recorded.jsonl
//!
//! Recording uses the coordinator's `BatcherConfig::trace` hook: the
//! engine loop streams one event per admitted request (backdated
//! arrival instant, model, n, seed, priority) and per executed step
//! (model, observed wall cost). `sim::assemble_trace` groups the events
//! by model into sim queues — per-queue step cost is the mean observed
//! cost — so the recorded traffic *shape* replays in exact virtual time
//! on any machine, however noisy the recording box was. CI replays a
//! checked-in smoke trace (which exercises preemption) plus a fresh
//! recording on every run.

use std::collections::BTreeMap;
use std::process::exit;
use std::sync::mpsc;
use std::time::Duration;

use ssmd::coordinator::sched::{QueuePolicy, SchedConfig};
use ssmd::coordinator::{
    BatcherConfig, Coordinator, EngineModel, GenRequest, ModelMap,
    SamplerChoice,
};
use ssmd::engine::{MockModel, SpecParams, Window};
use ssmd::sim::{assemble_trace, p95, read_trace, simulate,
                simulate_fleet_opts, write_trace, Arrival, FleetOptions,
                FleetScript, QueueGeometry, QueueSpec, Selector};
use ssmd::util::args::Args;

fn main() {
    let args = Args::from_env();
    // --expect-preemptions: fail unless the replay actually exercised
    // the preemption path (CI passes it for the checked-in smoke trace,
    // whose whole point is covering checkpoint/evict/park/resume — a
    // silent preemptions==0 would mean the gate went dead).
    let expect_preempt = args.bool("expect-preemptions");
    // --expect-faults: fail unless the replay actually exercised the
    // failure layer (CI passes it for the checked-in chaos trace, whose
    // point is covering fault containment, retries, the breaker, and
    // deadline sheds — all-zero counters would mean the gate went dead).
    let expect_faults = args.bool("expect-faults");
    // --engines N (default 1): N>1 replays through the fleet sim —
    // replicas on one shared clock, replica-kill scripts honoured.
    let engines = args.usize("engines", 1);
    if let Some(path) = args.opt_str("record") {
        record(&path);
        replay(&path, engines, expect_preempt, expect_faults);
    } else if let Some(path) = args.opt_str("replay") {
        replay(&path, engines, expect_preempt, expect_faults);
    } else {
        eprintln!(
            "usage: trace_replay --replay TRACE.jsonl [--engines N] \
             [--expect-preemptions] [--expect-faults] | \
             --record OUT.jsonl"
        );
        exit(2);
    }
}

/// Replay `path` twice through the sim harness and require the two
/// reports — every counter and every token stream — to be bitwise
/// identical. Prints a per-queue summary of the (stable) replay.
fn replay(path: &str, engines: usize, expect_preempt: bool,
          expect_faults: bool) {
    let (cfg, specs, trace, fleet) =
        match read_trace(std::path::Path::new(path)) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("FAIL reading {path}: {e}");
                exit(1);
            }
        };
    if engines > 1 || !fleet.is_empty() {
        replay_fleet(path, &cfg, &specs, &trace, &fleet, engines.max(1),
                     expect_faults);
        return;
    }
    println!(
        "replaying {path}: {} queues, {} arrivals",
        specs.len(),
        trace.len()
    );
    let a = simulate(&specs, &trace, Selector::Weighted, &cfg);
    let b = simulate(&specs, &trace, Selector::Weighted, &cfg);
    if a != b {
        eprintln!(
            "FAIL {path}: two replays diverged (steps {:?} vs {:?}, \
             shed {}/{} vs {}/{}, violations {} vs {})",
            a.steps, b.steps, a.shed_requests, a.shed, b.shed_requests,
            b.shed, a.slo_violations, b.slo_violations
        );
        exit(1);
    }
    for (i, w) in a.waits.iter().enumerate() {
        let p = if w.is_empty() { 0.0 } else { p95(w) };
        println!(
            "  q{i}: steps={} finished={} p95_wait={:.4}s",
            a.steps[i], a.finished[i], p
        );
    }
    println!(
        "  totals: shed={}req/{}seq slo_violations={} preempt_fires={} \
         preemptions={} resumes={} t_end={:.3}s",
        a.shed_requests, a.shed, a.slo_violations, a.preempt_fires,
        a.preemptions, a.resumes, a.t_end
    );
    println!(
        "  faults: engine_faults={} retries={} failed={:?} \
         deadline_sheds={} breaker_opens={} breaker_shed={}",
        a.engine_faults, a.retries, a.failed, a.deadline_sheds,
        a.breaker_opens, a.breaker_shed
    );
    if expect_preempt && a.preemptions == 0 {
        eprintln!(
            "FAIL {path}: --expect-preemptions set but the replay never \
             preempted (the preemption coverage this trace exists for \
             is dead)"
        );
        exit(1);
    }
    if expect_faults
        && (a.engine_faults == 0
            || a.retries == 0
            || a.deadline_sheds == 0
            || a.breaker_opens == 0
            || a.breaker_shed == 0)
    {
        eprintln!(
            "FAIL {path}: --expect-faults set but the replay left part \
             of the failure layer unexercised (engine_faults={} \
             retries={} deadline_sheds={} breaker_opens={} \
             breaker_shed={})",
            a.engine_faults, a.retries, a.deadline_sheds,
            a.breaker_opens, a.breaker_shed
        );
        exit(1);
    }
    println!("OK: replay is bitwise-stable");
}

/// Fleet replay: run the trace through `simulate_fleet_opts` twice and
/// require bitwise-identical reports; if the trace scripts replica
/// kills, additionally replay a kill-free same-seed fleet and require
/// every token stream the chaos run retired — evacuated or not — to be
/// bitwise identical to the undisturbed run's. With `expect_faults`,
/// fail unless the kill actually fired (checkpoints evacuated, victim
/// restarted) *and* the fleet still answered every admitted sequence.
fn replay_fleet(path: &str, cfg: &SchedConfig, specs: &[QueueSpec],
                trace: &[Arrival], fleet: &FleetScript, engines: usize,
                expect_faults: bool) {
    let opts = fleet.options(false);
    println!(
        "fleet-replaying {path}: {} queues, {} arrivals, {} replicas, \
         {} kill scripts",
        specs.len(),
        trace.len(),
        engines,
        fleet.replica_faults.len()
    );
    let a = simulate_fleet_opts(specs, trace, engines, cfg, opts.clone());
    let b = simulate_fleet_opts(specs, trace, engines, cfg, opts.clone());
    if a != b {
        eprintln!(
            "FAIL {path}: two fleet replays diverged (steps {:?} vs {:?}, \
             evacuations {} vs {}, restarts {} vs {})",
            a.steps, b.steps, a.evacuations, b.evacuations,
            a.replica_restarts, b.replica_restarts
        );
        exit(1);
    }
    // Evacuation must not perturb a single token: every stream the
    // chaos run retired must match the kill-free same-seed fleet's
    // stream for the same (arrival, sequence) key.
    if !fleet.replica_faults.is_empty() {
        let calm = simulate_fleet_opts(specs, trace, engines, cfg,
                                       FleetOptions {
                                           replica_faults: Vec::new(),
                                           ..opts
                                       });
        for (k, stream) in &a.tokens {
            if calm.tokens.get(k) != Some(stream) {
                eprintln!(
                    "FAIL {path}: evacuated stream for arrival {} seq {} \
                     differs from the kill-free same-seed run",
                    k.0, k.1
                );
                exit(1);
            }
        }
    }
    let done: usize = a.finished.iter().sum();
    println!(
        "  fleet: admitted={} done={done} failed={} deadline_sheds={} \
         shed={} brownout_shed={} migrations={} evacuations={} \
         replica_restarts={} t_end={:.3}s",
        a.admitted, a.failed, a.deadline_sheds, a.shed, a.brownout_shed,
        a.migrations, a.evacuations, a.replica_restarts, a.t_end
    );
    if expect_faults
        && (a.evacuations == 0
            || a.replica_restarts == 0
            || a.failed != 0
            || done != a.admitted)
    {
        eprintln!(
            "FAIL {path}: --expect-faults set but the replica-loss layer \
             went unexercised or lossy (evacuations={} replica_restarts={} \
             failed={} done={done}/{} admitted)",
            a.evacuations, a.replica_restarts, a.failed, a.admitted
        );
        exit(1);
    }
    println!("OK: fleet replay is bitwise-stable and loss-free");
}

/// Drive a synthetic live workload (bulk flood + latency burst) against
/// a real Coordinator with the trace hook armed, then assemble and
/// write the recorded trace.
fn record(path: &str) {
    let (tx, rx) = mpsc::channel();
    let mut sched =
        SchedConfig { preempt_after: 2, ..SchedConfig::default() };
    sched.per_model.insert("bulk".into(), QueuePolicy {
        preempt: true,
        ..QueuePolicy::default()
    });
    sched.per_model.insert("slo".into(), QueuePolicy {
        weight: 4.0,
        slo_p95_s: Some(0.05),
        ..QueuePolicy::default()
    });
    let geometry = vec![
        QueueGeometry {
            model: "bulk".into(),
            d: 32,
            vocab: 6,
            bucket: 4,
            model_seed: 7,
            policy: sched.resolve("bulk"),
        },
        QueueGeometry {
            model: "slo".into(),
            d: 8,
            vocab: 6,
            bucket: 1,
            model_seed: 11,
            policy: sched.resolve("slo"),
        },
    ];
    // The record path drives a real Coordinator like a serving client:
    // failures must report and exit nonzero, not panic a worker thread
    // mid-recording (repolint serve-no-unwrap pins this).
    // lint: serve-region
    let c = match Coordinator::start(
        || {
            let mut m: ModelMap = BTreeMap::new();
            let mut bulk = MockModel::new(32, 6, 7);
            bulk.buckets = vec![4];
            m.insert("bulk".into(), Box::new(bulk) as Box<dyn EngineModel>);
            let mut slo = MockModel::new(8, 6, 11);
            slo.buckets = vec![1];
            m.insert("slo".into(), Box::new(slo) as Box<dyn EngineModel>);
            Ok(m)
        },
        BatcherConfig {
            max_wait: Duration::from_millis(1),
            sched,
            trace: Some(tx),
            ..Default::default()
        },
    ) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("FAIL: coordinator boot: {e}");
            exit(1);
        }
    };

    // Bulk flood in the background; a latency burst rides on top.
    let bulk = c.clone();
    let t_bulk = std::thread::spawn(move || {
        bulk.generate(GenRequest {
            model: "bulk".into(),
            n_samples: 12,
            sampler: SamplerChoice::Speculative(SpecParams {
                window: Window::Constant(1),
                ..Default::default()
            }),
            seed: 41,
            ..Default::default()
        })
    });
    let mut slo_handles = Vec::new();
    for k in 0..4u64 {
        let slo = c.clone();
        slo_handles.push(std::thread::spawn(move || {
            slo.generate(GenRequest {
                model: "slo".into(),
                n_samples: 2,
                sampler: SamplerChoice::Speculative(SpecParams {
                    window: Window::Constant(1),
                    ..Default::default()
                }),
                seed: 100 + k,
                priority: Some(1),
                ..Default::default()
            })
        }));
    }
    let n_bulk = match t_bulk.join() {
        Ok(Ok(resp)) => resp.samples.len(),
        Ok(Err(e)) => {
            eprintln!("FAIL: bulk generate: {e}");
            exit(1);
        }
        Err(_) => {
            eprintln!("FAIL: bulk client thread panicked");
            exit(1);
        }
    };
    let mut n_slo = 0usize;
    for h in slo_handles {
        match h.join() {
            Ok(Ok(resp)) => n_slo += resp.samples.len(),
            Ok(Err(e)) => {
                eprintln!("FAIL: slo generate: {e}");
                exit(1);
            }
            Err(_) => {
                eprintln!("FAIL: slo client thread panicked");
                exit(1);
            }
        }
    }
    c.shutdown();
    println!("recorded live run: {n_bulk} bulk + {n_slo} slo samples");

    // The engine thread holds a clone of the sender until shutdown; by
    // now (both requests answered) every event of interest is buffered.
    let events: Vec<_> = rx.try_iter().collect();
    let n_arrivals = events
        .iter()
        .filter(|e| matches!(e, ssmd::sim::TraceEvent::Arrival { .. }))
        .count();
    if n_arrivals < 5 {
        eprintln!("FAIL: expected 5 recorded arrivals, got {n_arrivals}");
        exit(1);
    }
    let (specs, arrivals) = assemble_trace(&events, &geometry);
    let cfg = SchedConfig { preempt_after: 2, ..SchedConfig::default() };
    if let Err(e) =
        write_trace(std::path::Path::new(path), &cfg, &specs, &arrivals)
    {
        eprintln!("FAIL writing {path}: {e}");
        exit(1);
    }
    // lint: end-serve-region
    println!(
        "wrote {path}: {} queues, {} arrivals (mean step costs {:?})",
        specs.len(),
        arrivals.len(),
        specs.iter().map(|s| s.step_cost).collect::<Vec<_>>()
    );
}
