//! Infilling demo: condition on an arbitrarily-located prompt and fill the
//! rest in any order — the ordering flexibility the paper motivates for
//! MDMs (and which strict left-to-right self-speculative models lack).
//!
//!   cargo run --release --example infill -- --artifacts artifacts \
//!       --model text8 --prefix "the " --middle " and "

use anyhow::Result;
use ssmd::coordinator::{EngineModel, SamplerChoice};
use ssmd::engine::{Prompt, SpecParams, Window};
use ssmd::harness;
use ssmd::oracle::decode_chars;
use ssmd::util::args::Args;
use ssmd::util::rng::Pcg;

fn encode_char(c: char) -> i32 {
    if c == ' ' {
        0
    } else {
        (c as u8 - b'a') as i32 + 1
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let model_name = args.str("model", "text8");
    let n = args.usize("n", 3);

    let (_rt, _m, models) =
        harness::load_models(&artifacts, &[&model_name])?;
    let model = &models[&model_name];
    let d = EngineModel::seq_len(model);

    // Pin a prefix at the start and a fragment in the middle.
    let prefix = args.str("prefix", "za ");
    let middle = args.str("middle", " bo ");
    let mut prompt = Prompt::empty(d);
    for (i, c) in prefix.chars().enumerate().take(d) {
        prompt.0[i] = Some(encode_char(c));
    }
    let mid_start = d / 2;
    for (i, c) in middle.chars().enumerate() {
        if mid_start + i < d {
            prompt.0[mid_start + i] = Some(encode_char(c));
        }
    }

    let sampler = SamplerChoice::Speculative(SpecParams {
        window: Window::Cosine { dtau: 0.03 },
        n_verify: 2,
        ..Default::default()
    });
    let mut rng = Pcg::new(args.u64("seed", 7));
    let prompts = vec![prompt.clone(); n];
    let samples = model.sample(&prompts, &sampler, &mut rng)?;

    println!("prompt (fixed chars shown, '_' generated):");
    let mask_view: String = prompt
        .0
        .iter()
        .map(|s| match s {
            Some(t) => {
                if *t == 0 {
                    ' '
                } else {
                    (b'a' + (*t as u8) - 1) as char
                }
            }
            None => '_',
        })
        .collect();
    println!("  [{mask_view}]");
    for (i, s) in samples.iter().enumerate() {
        println!("infill {i} (nfe {:.1}): [{}]", s.nfe,
                 decode_chars(&s.tokens));
        // Prompt positions must be intact.
        for (pos, slot) in prompt.0.iter().enumerate() {
            if let Some(t) = slot {
                assert_eq!(s.tokens[pos], *t, "prompt violated at {pos}");
            }
        }
    }
    println!("(prompt positions verified intact in all samples)");
    Ok(())
}
