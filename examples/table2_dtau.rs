//! Table 2 (App. F): influence of the cosine-window parameter dtau on
//! spelling accuracy and NFE, with verify steps held at 1.
//!
//! Paper values (text8, 150M model):
//!   dtau 0.01 -> 0.91 acc / 80 NFE      dtau 0.04  -> 0.88 / 28
//!   dtau 0.02 -> 0.90 acc / 44 NFE      dtau 0.083 -> 0.87 / 21
//! The expected *shape*: NFE falls steeply with dtau while accuracy decays
//! slowly (until too many tokens are revealed early in generation).
//!
//!   cargo run --release --example table2_dtau -- --artifacts artifacts

use anyhow::Result;
use ssmd::harness::{self, fmt_f, spec_sweep, Table};
use ssmd::oracle::{spelling_accuracy, BigramOracle};
use ssmd::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let n_samples = args.usize("samples", 128);

    let (_rt, manifest, models) =
        harness::load_models(&artifacts, &["text8"])?;
    let model = &models["text8"];
    let d = ssmd::coordinator::EngineModel::seq_len(model);
    let oracle = BigramOracle::from_spec_file(
        manifest.specs.get("text8").expect("spec").to_str().unwrap())?;

    let dtaus = [0.01, 0.02, 0.04, 0.083];
    let settings: Vec<(usize, f64)> =
        dtaus.iter().map(|&dt| (1usize, dt)).collect();
    let points = spec_sweep(model, &settings, n_samples,
                            args.u64("seed", 0))?;

    println!("# Table 2 — dtau influence (1 verify step, {n_samples} \
              samples/point)\n");
    let mut t = Table::new(&["dtau", "accuracy", "NFE", "paper acc",
                             "paper NFE"]);
    let paper = [(0.01, 0.91, 80.0), (0.02, 0.90, 44.0),
                 (0.04, 0.88, 28.0), (0.083, 0.87, 21.0)];
    for (p, (dt, pa, pn)) in points.iter().zip(paper) {
        let acc = spelling_accuracy(&p.samples, d, &oracle.lexicon);
        t.row(vec![
            format!("{dt}"),
            fmt_f(acc, 3),
            fmt_f(p.nfe, 1),
            fmt_f(pa, 2),
            fmt_f(pn, 0),
        ]);
    }
    t.print();
    println!("\n(paper columns are the published 150M/D=256 values; ours is \
              a small-scale reproduction — compare the trend, not the \
              absolutes)");
    Ok(())
}
