//! Table 1: judge NLL (oracle bigram NLL replacing GPT2 — see DESIGN.md
//! substitutions) and unigram entropy at fixed NFE levels, for:
//!   masked diffusion, speculative (ours), SDTT, and the two ablations
//!   (no output residual, 2-causal-block).
//!
//! Each method's metric-NFE curve is traced by sweeping sampler settings;
//! values at each NFE level are read off by linear interpolation between
//! the two nearest points (the paper's Table 1 protocol).
//!
//!   cargo run --release --example table1_owt -- --artifacts artifacts \
//!       --samples 96

use anyhow::Result;
use ssmd::coordinator::EngineModel;
use ssmd::harness::{self, fmt_opt, interp_at, mdm_sweep, spec_sweep, Table};
use ssmd::oracle::{unigram_entropy, BigramOracle};
use ssmd::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let n_samples = args.usize("samples", 96);
    let seed = args.u64("seed", 0);

    let names = ["owt", "owt_nores", "owt_2c", "sdtt"];
    let (_rt, manifest, models) = harness::load_models(&artifacts, &names)?;
    let oracle = BigramOracle::from_spec_file(
        manifest.specs.get("owt").expect("owt spec").to_str().unwrap())?;
    let d = EngineModel::seq_len(&models["owt"]);

    // Our D=64 analog of the paper's {32,64,128,256} @ D=1024.
    let nfe_levels = [8.0, 16.0, 32.0, 48.0];
    // Sweep settings (Table 4 style).
    let spec_settings: &[(usize, f64)] =
        &[(1, 0.005), (1, 0.01), (2, 0.02), (3, 0.04), (4, 0.083),
          (6, 0.125)];
    let mdm_steps = [4usize, 8, 16, 24, 32, 48, 64];

    type Curve = Vec<(f64, f64, f64)>; // (nfe, nll, entropy)
    let metricize = |points: &[harness::CurvePoint]| -> Curve {
        points
            .iter()
            .map(|p| {
                (
                    p.nfe,
                    oracle.mean_nll(&p.samples, d),
                    unigram_entropy(&p.samples, d),
                )
            })
            .collect()
    };

    let mut curves: Vec<(String, Curve)> = Vec::new();
    println!("sweeping masked diffusion (owt draft half)...");
    curves.push((
        "Masked Diffusion".into(),
        metricize(&mdm_sweep(&models["owt"], &mdm_steps, n_samples, seed)?),
    ));
    println!("sweeping speculative (ours)...");
    curves.push((
        "Speculative (ours)".into(),
        metricize(&spec_sweep(&models["owt"], spec_settings, n_samples,
                              seed)?),
    ));
    println!("sweeping SDTT...");
    curves.push((
        "SDTT".into(),
        metricize(&mdm_sweep(&models["sdtt"], &mdm_steps, n_samples, seed)?),
    ));
    println!("sweeping ablation: no output residual...");
    curves.push((
        "No output residual".into(),
        metricize(&spec_sweep(&models["owt_nores"], spec_settings,
                              n_samples, seed)?),
    ));
    println!("sweeping ablation: 2nc-2c layers...");
    curves.push((
        "2nc-2c layers".into(),
        metricize(&spec_sweep(&models["owt_2c"], spec_settings, n_samples,
                              seed)?),
    ));

    println!("\n# Table 1 — oracle NLL (nats/token; judge = true bigram \
              process) and unigram entropy (nats)\n");
    println!("data reference: oracle NLL of real corpus windows = entropy \
              rate {:.3} nats/token\n", oracle.entropy_rate());
    let mut header = vec!["method".to_string()];
    for l in nfe_levels {
        header.push(format!("NLL@{l}"));
    }
    for l in nfe_levels {
        header.push(format!("Ent@{l}"));
    }
    let mut t = Table::new(
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for (name, curve) in &curves {
        let nll_pts: Vec<(f64, f64)> =
            curve.iter().map(|&(n, nll, _)| (n, nll)).collect();
        let ent_pts: Vec<(f64, f64)> =
            curve.iter().map(|&(n, _, e)| (n, e)).collect();
        let mut row = vec![name.clone()];
        for l in nfe_levels {
            row.push(fmt_opt(interp_at(&nll_pts, l), 3));
        }
        for l in nfe_levels {
            row.push(fmt_opt(interp_at(&ent_pts, l), 3));
        }
        t.row(row);
    }
    t.print();
    println!("\nexpected shape (paper): ours matches MDM quality at ~half \
              the NFE with equal entropy; SDTT shows lower NLL *and* lower \
              entropy (mode seeking); both ablations trade off worse than \
              ours.");
    Ok(())
}
