//! Figure 4: pLDDT(-proxy) vs NFE on the synthetic protein task.
//!
//! Mirrors the paper's Sec. 5.3 setup: a pretrained MDM backbone is frozen
//! and a single causal block fine-tuned on top (checkpoint `protein_head`).
//! The MDM baseline samples the *same frozen backbone* via its draft half —
//! exactly the paper's "original non-causal model with the standard MDM
//! algorithm" comparison. Quality = exact-likelihood pLDDT proxy (HMM
//! forward algorithm, DESIGN.md substitutions), mean over samples with SEM.
//!
//!   cargo run --release --example fig4_protein -- --artifacts artifacts \
//!       --samples 128

use anyhow::Result;
use ssmd::coordinator::EngineModel;
use ssmd::harness::{self, fmt_f, mdm_sweep, nfe_reduction, spec_sweep,
                    Table};
use ssmd::oracle::HmmOracle;
use ssmd::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let n_samples = args.usize("samples", 128);
    let seed = args.u64("seed", 0);

    let (_rt, manifest, models) =
        harness::load_models(&artifacts, &["protein_head"])?;
    let model = &models["protein_head"];
    let d = EngineModel::seq_len(model);
    let oracle = HmmOracle::from_spec_file(
        manifest.specs.get("protein").expect("spec").to_str().unwrap())?;

    let spec_settings: &[(usize, f64)] =
        &[(1, 0.01), (1, 0.02), (2, 0.04), (3, 0.083), (4, 0.125)];
    let mdm_steps = [4usize, 8, 16, 24, 32, 48, 64];

    println!("# Figure 4 — pLDDT proxy vs NFE (HMM protein, D={d}, \
              {n_samples} samples/point)\n");
    let mut t = Table::new(&["method", "setting", "NFE", "pLDDT", "SEM"]);
    let mut spec_curve = Vec::new();
    for p in spec_sweep(model, spec_settings, n_samples, seed)? {
        let (mean, sem) = oracle.plddt_mean_sem(&p.samples, d);
        spec_curve.push((p.nfe, mean));
        t.row(vec![
            "speculative".into(),
            p.label,
            fmt_f(p.nfe, 1),
            fmt_f(mean, 2),
            fmt_f(sem, 2),
        ]);
    }
    let mut mdm_curve = Vec::new();
    for p in mdm_sweep(model, &mdm_steps, n_samples, seed + 1)? {
        let (mean, sem) = oracle.plddt_mean_sem(&p.samples, d);
        mdm_curve.push((p.nfe, mean));
        t.row(vec![
            "mdm (frozen backbone)".into(),
            p.label,
            fmt_f(p.nfe, 1),
            fmt_f(mean, 2),
            fmt_f(sem, 2),
        ]);
    }
    t.print();

    // Reference: real HMM samples score ~85 by calibration.
    if let Some(f) = nfe_reduction(&spec_curve, &mdm_curve) {
        println!("\nheadline: ~{f:.2}x NFE reduction at matched pLDDT \
                  (paper: ~2x at high pLDDT)");
    }
    Ok(())
}
