//! Sharded-serving smoke gate (run by CI next to the chaos smoke gate).
//!
//! Two layers, both asserted:
//!
//! 1. **Virtual-time fleet sim** — replays a saturated mixed trace
//!    through `ssmd::sim::simulate_fleet` at 1 and 2 replicas and fails
//!    unless 2 replicas deliver >= 1.5x aggregate token throughput with
//!    bitwise-identical token streams, then replays a skewed burst and
//!    fails unless checkpoint migration actually fires (idle replica
//!    adopts mid-sequence work) at zero token drift.
//!
//! 2. **Live sharded coordinator** — boots `Coordinator::start_sharded`
//!    with 2 replica engine threads over a mock model, fires skewed
//!    deterministic requests (both replicas idle at send time, so the
//!    router lands each whole request on replica 0 and replica 1 can
//!    only get work by adopting a migrated checkpoint), and fails unless
//!    a live migration happens, every response matches the single-engine
//!    baseline bitwise, and the per-replica health/metrics surfaces
//!    (`engines` array, `_e{id}` suffixes, `migrations` counter) are
//!    populated.
//!
//!   cargo run --release --example fleet_smoke

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::{anyhow, Result};
use ssmd::coordinator::sched::{QueuePolicy, SchedConfig};
use ssmd::coordinator::{
    BatcherConfig, Coordinator, EngineModel, GenRequest, ModelMap,
    SamplerChoice,
};
use ssmd::engine::{MockModel, SpecParams, Window};
use ssmd::sim::{simulate_fleet, Arrival, QueueSpec};
use ssmd::util::json::Json;

fn sim_gate() -> Result<()> {
    let cfg = SchedConfig::default();

    // Saturated mixed trace: the replica-scaling headline.
    let specs = vec![
        QueueSpec::new(12, 2, 0.03, QueuePolicy::default()),
        QueueSpec::new(8, 1, 0.03, QueuePolicy {
            weight: 2.0,
            ..QueuePolicy::default()
        }),
    ];
    let trace: Vec<Arrival> = (0..24u64)
        .map(|k| Arrival {
            t: 0.01 * k as f64,
            queue: (k % 2) as usize,
            n: 2,
            seed: 5000 + k,
            ..Arrival::default()
        })
        .collect();
    let one = simulate_fleet(&specs, &trace, 1, &cfg, false);
    let two = simulate_fleet(&specs, &trace, 2, &cfg, true);
    if one.tokens != two.tokens {
        return Err(anyhow!("replica count changed a token stream"));
    }
    let ratio = two.token_throughput() / one.token_throughput();
    println!(
        "sim: 1 replica {:.0} tok/s, 2 replicas {:.0} tok/s ({ratio:.2}x)",
        one.token_throughput(),
        two.token_throughput()
    );
    if ratio < 1.5 {
        return Err(anyhow!("throughput scaling {ratio:.2}x < 1.5x"));
    }

    // Skewed burst: one 8-sequence arrival routes whole to replica 0;
    // replica 1 can only work by adopting a migrated checkpoint.
    let specs = vec![QueueSpec::new(8, 4, 0.05, QueuePolicy::default())];
    let burst = vec![Arrival { n: 8, seed: 77, ..Arrival::default() }];
    let single = simulate_fleet(&specs, &burst, 1, &cfg, false);
    let moved = simulate_fleet(&specs, &burst, 2, &cfg, true);
    if moved.migrations == 0 || moved.finished[1] == 0 {
        return Err(anyhow!(
            "skewed burst exercised no migration \
             (migrations {}, finished on replica 1: {})",
            moved.migrations, moved.finished[1]
        ));
    }
    if moved.tokens != single.tokens {
        return Err(anyhow!("migration changed a token stream bitwise"));
    }
    println!(
        "sim: skewed burst migrated {} checkpoint(s), {} finished on the \
         adopter, tokens bitwise identical",
        moved.migrations, moved.finished[1]
    );
    Ok(())
}

fn mock_factory()
    -> impl Fn() -> Result<ModelMap> + Clone + Send + 'static {
    || {
        let mut map: ModelMap = BTreeMap::new();
        map.insert(
            "mock".into(),
            Box::new(MockModel::new(64, 12, 0x51d)) as Box<dyn EngineModel>,
        );
        Ok(map)
    }
}

fn live_request(seed: u64) -> GenRequest {
    GenRequest {
        model: "mock".into(),
        n_samples: 16,
        sampler: SamplerChoice::Speculative(SpecParams {
            window: Window::Cosine { dtau: 0.02 },
            n_verify: 2,
            temperature: 0.7,
            ..Default::default()
        }),
        seed,
        deterministic: true,
        ..Default::default()
    }
}

fn live_gate() -> Result<()> {
    let cfg = || BatcherConfig {
        max_wait: Duration::from_millis(1),
        ..Default::default()
    };
    // The whole live gate is a request-admission path against real
    // coordinators: every failure must surface as an `Err`, never a
    // panic (repolint serve-no-unwrap pins this).
    // lint: serve-region
    let baseline = Coordinator::start(mock_factory(), cfg())?;
    let fleet = Coordinator::start_sharded(mock_factory(), cfg(), 2)?;

    let expect = baseline.generate(live_request(4242))?;
    let mut migrated = 0u64;
    // Each attempt is a fresh skewed load (both replicas idle at send
    // time -> the whole request lands on replica 0). Wall-clock timing
    // decides *when* replica 1's idle poll sees the migration board, so
    // retry until one fires; token equality is asserted on every try.
    for _ in 0..200 {
        let got = fleet.generate(live_request(4242))?;
        if got.samples.len() != expect.samples.len() {
            return Err(anyhow!("sharded sample count diverged"));
        }
        for (a, b) in expect.samples.iter().zip(&got.samples) {
            if a.tokens != b.tokens {
                return Err(anyhow!(
                    "sharded response diverged from single-engine \
                     baseline bitwise"
                ));
            }
        }
        let h = fleet.health()?;
        migrated = h
            .get("migrations")
            .and_then(|m| m.as_f64())
            .unwrap_or(0.0) as u64;
        if migrated >= 1 {
            break;
        }
    }
    if migrated == 0 {
        return Err(anyhow!("no live migration fired in 200 attempts"));
    }

    let h = fleet.health()?;
    if h.get("ok").and_then(|b| b.as_bool()) != Some(true) {
        return Err(anyhow!("sharded /healthz not ok"));
    }
    let n_engines = match h.get("engines") {
        Some(Json::Arr(engines)) => engines.len(),
        _ => 0,
    };
    if n_engines != 2 {
        return Err(anyhow!("health engines array has {n_engines} entries"));
    }
    let snap = fleet.metrics.snapshot();
    // Replica 0 is the migration origin, so its suffixed counters must
    // exist (the bare fleet-wide `migrations` lives in /healthz).
    for name in ["requests_e0", "requests_e1", "migrations_e0"] {
        let present = snap
            .get("counters")
            .and_then(|c| c.get(name))
            .is_some();
        if !present {
            return Err(anyhow!("metrics snapshot missing '{name}'"));
        }
    }
    println!(
        "live: {migrated} migration(s), responses bitwise identical to \
         single-engine, per-replica health + metrics populated"
    );
    baseline.shutdown();
    fleet.shutdown();
    // lint: end-serve-region
    Ok(())
}

fn main() -> Result<()> {
    sim_gate()?;
    live_gate()?;
    println!("fleet smoke: PASS");
    Ok(())
}
