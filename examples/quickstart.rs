//! Quickstart: load a model from `artifacts/`, sample with both the
//! speculative sampler (Alg. 3) and the MDM baseline, and compare NFE.
//!
//!   cargo run --release --example quickstart -- --artifacts artifacts \
//!       --model owt --n 4
//!
//! Requires `make artifacts` (which itself requires trained checkpoints in
//! python/runs — see README "Reproduce").

use anyhow::Result;
use ssmd::coordinator::{EngineModel, SamplerChoice};
use ssmd::engine::{MdmParams, Prompt, SpecParams, Window};
use ssmd::harness;
use ssmd::util::args::Args;
use ssmd::util::rng::Pcg;

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = args.str("artifacts", "artifacts");
    let model_name = args.str("model", "owt");
    let n = args.usize("n", 4);

    let (_rt, _manifest, models) =
        harness::load_models(&artifacts, &[&model_name])?;
    let model = &models[&model_name];
    let d = EngineModel::seq_len(model);
    let prompts = vec![Prompt::empty(d); n];

    // --- the paper's sampler: one draft pass + speculative verification ---
    let mut rng = Pcg::new(args.u64("seed", 0));
    let spec = SamplerChoice::Speculative(SpecParams {
        window: Window::Cosine { dtau: 0.05 },
        n_verify: 2,
        ..Default::default()
    });
    let spec_samples = model.sample(&prompts, &spec, &mut rng)?;

    // --- the baseline: standard masked diffusion on a cosine grid --------
    let mut rng = Pcg::new(args.u64("seed", 0));
    let mdm = SamplerChoice::Mdm(MdmParams { steps: 64, temperature: 1.0 });
    let mdm_samples = model.sample(&prompts, &mdm, &mut rng)?;

    let mean_nfe =
        |v: &[ssmd::engine::Sample]| {
            v.iter().map(|s| s.nfe).sum::<f64>() / v.len() as f64
        };
    println!("model '{model_name}' (D={d})");
    println!("speculative: mean NFE {:.1}", mean_nfe(&spec_samples));
    println!("mdm (K=64):  mean NFE {:.1}", mean_nfe(&mdm_samples));
    println!();
    for (i, s) in spec_samples.iter().enumerate() {
        println!(
            "spec sample {i} (nfe {:.1}, {} accepted / {} rejected): {:?}",
            s.nfe,
            s.accepted,
            s.rejected,
            &s.tokens[..16.min(s.tokens.len())]
        );
    }
    Ok(())
}
